"""StateMatrix: the incrementally-maintained packed metadata plane.

OREO's decision loop is metadata-only: every query is scored against every
candidate layout's zone maps.  Before this plane existed, the hot path
re-padded all S states' metadata into a fresh ``(S, P_max, C)`` tensor per
query (``layouts.eval_cost_states``).  :class:`StateMatrix` keeps that packed
representation *persistent* — padded ``mins``/``maxs``, ``rows``, ``totals``
and id <-> slot maps — updated in O(P*C) on :meth:`register` /
:meth:`deregister` instead of rebuilt in O(S*P*C) per query.

Scoring details (numpy backend, the default):

* bounds are also stored column-major (``(C, S, P)``) so the per-query
  overlap test broadcasts over *leading* axes — numpy's inner loops then run
  over contiguous (S, P) planes instead of the pathological length-C
  trailing axis;
* columns whose query bound is infinite (non-predicate columns — the common
  case for template workloads) are skipped outright: ``min <= +inf`` and
  ``max >= -inf`` are identically True, so the skipped comparisons cannot
  change the scan matrix;
* the final reduction uses :func:`repro.core.layouts.scanned_dot` (one
  contiguous einsum kernel for single and batched signatures), so estimates
  are bit-identical to ``eval_cost_states`` and per-state ``eval_cost``.

The ``pallas`` backend routes the overlap test through
:func:`repro.engine.compute.scan_matrix` (float32 kernel; see that module
for the exactness caveat).
"""
from __future__ import annotations

import warnings
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core import layouts as L

from . import compute


class StateMatrix:
    """Persistent packed zone maps for all registered layout states."""

    def __init__(self, compute_backend: str = "numpy",
                 state_capacity: int = 8):
        if compute_backend not in compute.BACKENDS:
            raise ValueError(f"unknown compute backend: {compute_backend!r}")
        self.compute_backend = compute_backend
        self._scap = max(int(state_capacity), 1)
        self._pcap = 0
        self._c: Optional[int] = None
        self._n = 0
        self._ids: List[int] = []              # slot -> state id
        self._slots: Dict[int, int] = {}       # state id -> slot
        self._counts: List[int] = []           # slot -> partition count
        self._totals: List[int] = []           # slot -> max(total_rows, 1)
        self._rows_exact: List[np.ndarray] = []  # slot -> contiguous (P_s,) f64
        self._mins: Optional[np.ndarray] = None    # (S_cap, P_cap, C)
        self._maxs: Optional[np.ndarray] = None
        self._minsT: Optional[np.ndarray] = None   # (C, S_cap, P_cap)
        self._maxsT: Optional[np.ndarray] = None
        self._rows: Optional[np.ndarray] = None    # (S_cap, P_cap) f64
        self._totals_arr: Optional[np.ndarray] = None  # (S_cap,) f64
        self._uniform = True    # all counts == P_cap -> batched reduction
        #: Bumped on every register/deregister; consumers may key caches on it.
        self.version = 0
        #: Mirror hooks (see :class:`repro.engine.fleet_matrix.FleetMatrix`):
        #: each listener's ``on_register(state_id, meta)`` /
        #: ``on_deregister(state_id)`` fires *after* the plane update, in the
        #: same order the plane saw it, so a mirror replaying the events with
        #: the same swap-with-last algorithm assigns identical slots.
        self._listeners: List = []

    def _add_listener(self, listener) -> None:
        self._listeners.append(listener)

    def _remove_listener(self, listener) -> None:
        self._listeners.remove(listener)

    def add_listener(self, listener) -> None:
        """Deprecated alias of the internal ``_add_listener`` hook."""
        warnings.warn("StateMatrix listener plumbing is internal mirror "
                      "machinery; add_listener is now _add_listener",
                      DeprecationWarning, stacklevel=2)
        self._add_listener(listener)

    def remove_listener(self, listener) -> None:
        """Deprecated alias of the internal ``_remove_listener`` hook."""
        warnings.warn("StateMatrix listener plumbing is internal mirror "
                      "machinery; remove_listener is now _remove_listener",
                      DeprecationWarning, stacklevel=2)
        self._remove_listener(listener)

    # -- introspection --------------------------------------------------
    def __len__(self) -> int:
        return self._n

    def __contains__(self, state_id: int) -> bool:
        return state_id in self._slots

    @property
    def state_ids(self) -> List[int]:
        """Registered state ids in slot order."""
        return list(self._ids)

    @property
    def num_columns(self) -> Optional[int]:
        return self._c

    @property
    def partition_capacity(self) -> int:
        return self._pcap

    @property
    def uniform(self) -> bool:
        """True when every registered state fills the full partition width,
        i.e. :meth:`estimate` reduces via the batched einsum path."""
        return self._uniform

    def slot(self, state_id: int) -> int:
        """Packed slot index of a registered state (KeyError if unknown)."""
        return self._slots[state_id]

    def metadata(self, state_id: int) -> L.PartitionMetadata:
        """The registered state's exact zone maps (views into the plane)."""
        slot = self._slots[state_id]
        p = self._counts[slot]
        return L.PartitionMetadata(mins=self._mins[slot, :p],
                                   maxs=self._maxs[slot, :p],
                                   rows=self._rows[slot, :p])

    # -- allocation -----------------------------------------------------
    def _alloc(self, scap: int, pcap: int) -> None:
        c = self._c
        mins = np.full((scap, pcap, c), np.inf)
        maxs = np.full((scap, pcap, c), -np.inf)
        minsT = np.full((c, scap, pcap), np.inf)
        maxsT = np.full((c, scap, pcap), -np.inf)
        rows = np.zeros((scap, pcap))
        totals = np.ones(scap)
        n = self._n
        if n and self._mins is not None:
            old_p = self._pcap
            mins[:n, :old_p] = self._mins[:n]
            maxs[:n, :old_p] = self._maxs[:n]
            minsT[:, :n, :old_p] = self._minsT[:, :n]
            maxsT[:, :n, :old_p] = self._maxsT[:, :n]
            rows[:n, :old_p] = self._rows[:n]
            totals[:n] = self._totals_arr[:n]
        self._mins, self._maxs = mins, maxs
        self._minsT, self._maxsT = minsT, maxsT
        self._rows, self._totals_arr = rows, totals
        self._scap, self._pcap = scap, pcap

    def _refresh_uniform(self) -> None:
        self._uniform = all(p == self._pcap for p in self._counts)

    # -- maintenance (O(P*C) per call) ----------------------------------
    def register(self, state_id: int, meta: L.PartitionMetadata) -> None:
        """Add (or overwrite) one state's zone maps in the packed plane."""
        if self._c is None:
            self._c = meta.num_columns
        elif meta.num_columns != self._c:
            raise ValueError(
                f"state {state_id}: {meta.num_columns} columns, plane has "
                f"{self._c}")
        p = meta.num_partitions
        slot = self._slots.get(state_id)
        if slot is None:
            if self._mins is None or self._n == self._scap or p > self._pcap:
                self._alloc(max(self._scap, 2 * self._n, 1),
                            max(self._pcap, p))
            slot = self._n
            self._n += 1
            self._ids.append(state_id)
            self._slots[state_id] = slot
            self._counts.append(p)
            self._totals.append(1)
            self._rows_exact.append(np.zeros(0))
        elif p > self._pcap:
            self._alloc(self._scap, p)
        self._mins[slot, :p] = meta.mins
        self._mins[slot, p:] = np.inf
        self._maxs[slot, :p] = meta.maxs
        self._maxs[slot, p:] = -np.inf
        self._minsT[:, slot, :p] = meta.mins.T
        self._minsT[:, slot, p:] = np.inf
        self._maxsT[:, slot, :p] = meta.maxs.T
        self._maxsT[:, slot, p:] = -np.inf
        self._rows[slot, :p] = meta.rows
        self._rows[slot, p:] = 0.0
        total = max(meta.total_rows, 1)
        self._counts[slot] = p
        self._totals[slot] = total
        self._totals_arr[slot] = total
        self._rows_exact[slot] = L.self_rows(meta)
        self._refresh_uniform()
        self.version += 1
        for listener in self._listeners:
            listener.on_register(state_id, meta)

    def deregister(self, state_id: int) -> None:
        """Drop one state; the last slot is swapped into the hole (O(P*C)).
        Unknown ids are a no-op."""
        slot = self._slots.pop(state_id, None)
        if slot is None:
            return
        last = self._n - 1
        if slot != last:
            self._mins[slot] = self._mins[last]
            self._maxs[slot] = self._maxs[last]
            self._minsT[:, slot] = self._minsT[:, last]
            self._maxsT[:, slot] = self._maxsT[:, last]
            self._rows[slot] = self._rows[last]
            self._totals_arr[slot] = self._totals_arr[last]
            moved = self._ids[last]
            self._ids[slot] = moved
            self._slots[moved] = slot
            self._counts[slot] = self._counts[last]
            self._totals[slot] = self._totals[last]
            self._rows_exact[slot] = self._rows_exact[last]
        self._ids.pop()
        self._counts.pop()
        self._totals.pop()
        self._rows_exact.pop()
        self._n = last
        # Wipe the vacated slot back to the identity fill values.  Every
        # reader slices [:n], so stale bounds were latent — but a later
        # register that reuses the slot for a *narrower* state relies on
        # register() overwriting [p:] tails, and the FleetMatrix mirror
        # wipes its twin slot; keeping the source plane identical under
        # grower-driven register/deregister churn keeps plane snapshots
        # byte-comparable.
        self._mins[last] = np.inf
        self._maxs[last] = -np.inf
        self._minsT[:, last] = np.inf
        self._maxsT[:, last] = -np.inf
        self._rows[last] = 0.0
        self._totals_arr[last] = 1.0
        self._refresh_uniform()
        self.version += 1
        for listener in self._listeners:
            listener.on_deregister(state_id)

    # -- scoring --------------------------------------------------------
    def _scanned(self, q_lo: np.ndarray, q_hi: np.ndarray) -> np.ndarray:
        """(n, P_cap) bool scan matrix over all registered states."""
        n = self._n
        if self.compute_backend in ("pallas", "pallas_fused"):
            mins2d = self._mins[:n].reshape(n * self._pcap, self._c)
            maxs2d = self._maxs[:n].reshape(n * self._pcap, self._c)
            return compute.scan_matrix(q_lo[None], q_hi[None], mins2d,
                                       maxs2d,
                                       backend=self.compute_backend,
                                       )[0].reshape(n, self._pcap)
        return compute.masked_overlap(self._minsT[:, :n, :],
                                      self._maxsT[:, :n, :], q_lo, q_hi)

    def reduce_scanned(self, scanned: np.ndarray) -> np.ndarray:
        """Row-weighted reduction of an (n, P_cap) scan matrix to (n,) costs.

        The single reduction behind :meth:`estimate` — also invoked by
        :class:`repro.engine.fleet_matrix.FleetMatrix` on a per-tenant slice
        of its fused fleet-wide scan, so loop and batched fleet paths reduce
        through literally the same code on identical operands (bit-identity).
        ``scanned`` must be C-contiguous, exactly as :meth:`_scanned` emits.
        """
        n = self._n
        if self._uniform:
            # All states fill the full partition width: one batched einsum
            # (same contiguous kernel as scanned_dot, so still bit-exact).
            return (np.einsum("sp,sp->s", scanned, self._rows[:n])
                    / self._totals_arr[:n])
        out = np.empty(n)
        for s in range(n):
            out[s] = (L.scanned_dot(scanned[s, :self._counts[s]],
                                    self._rows_exact[s]) / self._totals[s])
        return out

    def estimate(self, q_lo: np.ndarray, q_hi: np.ndarray) -> np.ndarray:
        """Service cost c(s, q) of one query under every registered state.

        Returns float64 (n,) in slot order — bit-identical (numpy backend)
        to ``eval_cost_states`` / per-state ``eval_cost`` over the same
        metadata.
        """
        if self._n == 0:
            return np.zeros(0)
        return self.reduce_scanned(self._scanned(q_lo, q_hi))

    def estimate_costs(self, state_ids: Sequence[int], q_lo: np.ndarray,
                       q_hi: np.ndarray) -> Dict[int, float]:
        """Per-id costs for the requested states (scored all at once)."""
        ids = list(state_ids)
        if not ids:
            return {}
        costs = self.estimate(q_lo, q_hi)
        slots = self._slots
        return {s: float(costs[slots[s]]) for s in ids}
