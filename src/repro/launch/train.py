"""End-to-end training driver.

Runs any registered architecture (reduced ``--smoke`` configs on CPU; full
configs on real meshes) with the OREO-managed data pipeline, AdamW, remat,
checkpoint/restart, and metric logging.

Example (CPU, ~100M-param model, a few hundred steps):
    PYTHONPATH=src python -m repro.launch.train \
        --arch qwen3-1.7b --smoke --steps 200 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_arch
from repro.data import pipeline as dpipe
from repro.models import build_model
from repro.train import (FaultTolerantTrainer, OptimizerConfig, TrainOptions,
                         build_train_step, init_train_state)


def scale_config(cfg, d_model=None, n_layers=None, vocab=None):
    """Optionally resize a config (e.g. ~100M params for the CPU driver)."""
    updates = {}
    if d_model:
        updates["d_model"] = d_model
        updates["d_ff"] = d_model * 4
    if n_layers:
        updates["n_layers"] = n_layers
    if vocab:
        updates["vocab"] = vocab
    return dataclasses.replace(cfg, **updates) if updates else cfg


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--corpus-docs", type=int, default=20_000)
    ap.add_argument("--oreo-alpha", type=float, default=80.0)
    ap.add_argument("--d-model", type=int, default=None)
    ap.add_argument("--n-layers", type=int, default=None)
    ap.add_argument("--vocab", type=int, default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = scale_config(get_arch(args.arch, smoke=args.smoke),
                       d_model=args.d_model, n_layers=args.n_layers,
                       vocab=args.vocab)
    print(f"arch={cfg.name} family={cfg.family} params~{cfg.num_params():,}")
    model = build_model(cfg)
    opt_cfg = OptimizerConfig(peak_lr=args.lr, warmup_steps=20,
                              total_steps=args.steps)
    options = TrainOptions(microbatches=1)
    train_step = jax.jit(build_train_step(model, opt_cfg, options),
                        donate_argnums=0)
    state = init_train_state(model, jax.random.PRNGKey(0), opt_cfg, options)

    # OREO-managed data pipeline over a synthetic corpus.
    meta, tokens = dpipe.synth_corpus(args.corpus_docs, doc_len=args.seq,
                                      vocab=cfg.vocab)
    recipe = dpipe.mixture_recipe(meta, total_steps=args.steps + 1)
    pipe = dpipe.OreoDataPipeline(meta, tokens, recipe,
                                  batch_size=args.batch, seq_len=args.seq,
                                  alpha=args.oreo_alpha)
    pipe_iter = iter(pipe)
    cache = {}

    def batch_fn(step: int):
        # Deterministic per-step batches (replayable on restart).
        if step not in cache:
            cache[step] = {k: jnp.asarray(v)
                           for k, v in next(pipe_iter).items()}
            if cfg.embed_input:          # stub frontends take embeddings
                tok = cache[step].pop("tokens")
                emb = jax.random.normal(
                    jax.random.PRNGKey(step),
                    tok.shape + (cfg.d_model,), jnp.bfloat16)
                cache[step]["embeds"] = emb
        return cache[step]

    trainer = FaultTolerantTrainer(train_step, state, batch_fn,
                                   ckpt_dir=args.ckpt_dir,
                                   ckpt_every=args.ckpt_every)
    t0 = time.time()
    state = trainer.run(args.steps)
    dt = time.time() - t0
    losses = [m["loss"] for m in trainer.metrics_log]
    for m in trainer.metrics_log[::max(args.log_every, 1)]:
        print(f"step {m['step']:5d} loss {m['loss']:.4f} "
              f"lr {m['lr']:.2e} gnorm {m['grad_norm']:.2f}")
    print(f"\n{args.steps} steps in {dt:.1f}s "
          f"({args.steps * args.batch * args.seq / dt:.0f} tok/s)")
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f}")
    print(f"OREO pipeline: mean scan fraction "
          f"{pipe.stats.mean_scan_fraction:.3f}, reorgs {pipe.stats.reorgs}")
    out = {"first_loss": losses[0], "last_loss": losses[-1],
           "seconds": dt, "pipeline": dataclasses.asdict(pipe.stats)}
    with open(os.path.join(args.ckpt_dir, "train_summary.json"), "w") as f:
        json.dump(out, f, indent=1)


if __name__ == "__main__":
    main()
