"""Launch: production meshes, dry-run driver, roofline analysis, trainers."""
