"""Launch: production meshes, dry-run driver, roofline analysis, trainers,
and process-parallel shard hosting for the routing plane
(:mod:`repro.launch.shard_host`; import submodules directly — this
package stays import-light)."""
