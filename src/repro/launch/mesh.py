"""Production meshes + logical->physical sharding-spec resolution.

Single pod: 16x16 = 256 chips, axes (data, model).
Multi-pod:  2x16x16 = 512 chips, axes (pod, data, model) -- the pod axis
extends data parallelism (only gradient all-reduce crosses the pod links).

``make_production_mesh`` is a FUNCTION so importing this module never touches
jax device state.
"""
from __future__ import annotations

from typing import Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

SINGLE_POD = (16, 16)
MULTI_POD = (2, 16, 16)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = MULTI_POD if multi_pod else SINGLE_POD
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def batch_axes(multi_pod: bool) -> Tuple[str, ...]:
    return ("pod", "data") if multi_pod else ("data",)


# ---------------------------------------------------------------------------
# Logical spec resolution.  Model code emits PartitionSpecs over the logical
# vocabulary {"model", "fsdp", "batch", "seq2", None}; this maps them onto
# the physical mesh axes.
#   model -> "model"                         (tensor/expert parallel)
#   fsdp  -> "data"                          (ZeRO-3 param sharding, in-pod)
#   batch -> ("pod","data") | "data"         (data parallel)
#   seq2  -> ("data","model")                (long-context KV sequence shard)
# ---------------------------------------------------------------------------

def _resolve_element(el, multi_pod: bool):
    if el is None:
        return None
    if isinstance(el, (tuple, list)):
        out = []
        for e in el:
            r = _resolve_element(e, multi_pod)
            if r is None:
                continue
            out.extend(r if isinstance(r, tuple) else (r,))
        return tuple(out) if out else None
    if el == "model":
        return "model"
    if el == "fsdp":
        return "data"
    if el == "batch":
        return ("pod", "data") if multi_pod else "data"
    if el == "seq2":
        return ("data", "model")
    raise ValueError(f"unknown logical axis {el!r}")


def resolve_spec(spec: P, multi_pod: bool) -> P:
    return P(*[_resolve_element(el, multi_pod) for el in spec])


def resolve_tree(tree, multi_pod: bool):
    return jax.tree.map(lambda s: resolve_spec(s, multi_pod), tree,
                        is_leaf=lambda x: isinstance(x, P))


def named_tree(tree, mesh: Mesh, multi_pod: bool):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, resolve_spec(s, multi_pod)), tree,
        is_leaf=lambda x: isinstance(x, P))
