"""Per-(arch x shape) dry-run cell options.

Training memory levers (microbatching, sequence parallelism, optimizer-state
dtype) have per-arch defaults chosen so every train cell FITS the 16GB/chip
v5e budget on the single-pod mesh; EXPERIMENTS.md §Perf records the
baseline->optimized path that picked them.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.configs.base import SHAPES
from repro.train.optimizer import OptimizerConfig
from repro.train.train_loop import TrainOptions


@dataclasses.dataclass(frozen=True)
class CellOptions:
    train: TrainOptions
    opt: OptimizerConfig
    seq_parallel: bool
    # Decode-cache sequence-shard axes (logical): "model" default; long
    # batch=1 contexts spread over data+model ("seq2").
    cache_seq_axes: Tuple[str, ...] = ("model",)


# seq_parallel is a PER-ARCH decision (EXPERIMENTS §Perf A2/D1/D2): under
# XLA-SPMD the Megatron-SP residual constraint triggers whole-weight gathers
# inside the layer loop (bytes ~ d^2 per layer per microbatch), while SP-off
# pays full-sequence activation traffic (bytes ~ T*d per layer).  For
# d=18432 (nemotron) the weight gathers dominate -> SP off (collective
# -58%); for d<=4096 the activation traffic dominates -> SP on.
_TRAIN_DEFAULTS = {
    # arch -> (microbatches, seq_parallel, opt_state_dtype)
    "nemotron-4-340b": (16, False, "bfloat16"),
    "phi3.5-moe-42b-a6.6b": (4, True, "float32"),
    "moonshot-v1-16b-a3b": (4, False, "float32"),
    "chatglm3-6b": (4, True, "float32"),
    "minitron-4b": (4, True, "float32"),
    "qwen3-1.7b": (2, False, "float32"),
    "paligemma-3b": (2, False, "float32"),
    "musicgen-large": (4, False, "float32"),
    "rwkv6-3b": (4, False, "float32"),
    "zamba2-2.7b": (4, False, "float32"),
}


def cell_options(arch: str, shape_name: str,
                 microbatches: Optional[int] = None,
                 seq_parallel: Optional[bool] = None,
                 opt_dtype: Optional[str] = None) -> CellOptions:
    shape = SHAPES[shape_name]
    mb, sp, od = _TRAIN_DEFAULTS.get(arch, (1, False, "float32"))
    if microbatches is not None:
        mb = microbatches
    if seq_parallel is not None:
        sp = seq_parallel
    if opt_dtype is not None:
        od = opt_dtype
    if shape.kind != "train":
        mb, sp = 1, False
    cache_axes: Tuple[str, ...] = ("model",)
    if shape.name == "long_500k":
        # batch=1: spread the KV/cache sequence over data x model.
        cache_axes = ("seq2",)
    return CellOptions(
        train=TrainOptions(microbatches=mb),
        opt=OptimizerConfig(state_dtype=od),
        seq_parallel=sp,
        cache_seq_axes=cache_axes,
    )
