import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

os.environ.setdefault("TF_CPP_MIN_LOG_LEVEL", "2")   # silence SPMD warnings

# NOTE: the two lines above MUST run before any other import (including jax
# and repro.*): jax locks the device count at first backend initialization.
# The 512 host devices exist ONLY for this dry-run process; smoke tests and
# benchmarks see the real single CPU device.

import argparse          # noqa: E402
import collections       # noqa: E402
import json              # noqa: E402
import re                # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402

from repro.configs.base import SHAPES, get_arch, runnable_cells, skipped_cells  # noqa: E402
from repro.launch import cells as cell_opts                                     # noqa: E402
from repro.launch import hlo_cost                                               # noqa: E402
from repro.launch import mesh as mesh_lib                                       # noqa: E402
from repro.models import build_model, input_specs, sharding                     # noqa: E402
from repro.train.train_loop import (build_train_step, init_train_state,         # noqa: E402
                                    train_state_specs)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s+\S+\s+(" + "|".join(c + r"(?:-start)?" for c in _COLLECTIVES)
    + r")\(")


def parse_collective_bytes(hlo_text: str) -> dict:
    """Sum operand bytes of every collective op in the partitioned HLO."""
    per_type = collections.defaultdict(int)
    counts = collections.defaultdict(int)
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        op = m.group(1).replace("-start", "")
        operand_region = line[m.end():]
        total = 0
        for dtype, dims in _SHAPE_RE.findall(operand_region):
            if dtype not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            total += n * _DTYPE_BYTES[dtype]
        per_type[op] += total
        counts[op] += 1
    return {"bytes_by_type": dict(per_type),
            "counts_by_type": dict(counts),
            "total_bytes": int(sum(per_type.values()))}


def _mem_dict(compiled) -> dict:
    try:
        m = compiled.memory_analysis()
    except Exception:
        return {}
    if m is None:
        return {}
    out = {}
    for field in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "alias_size_in_bytes",
                  "generated_code_size_in_bytes"):
        if hasattr(m, field):
            out[field] = int(getattr(m, field))
    return out


def _cost_dict(compiled) -> dict:
    try:
        c = compiled.cost_analysis()
    except Exception:
        return {}
    if c is None:
        return {}
    if isinstance(c, (list, tuple)):
        c = c[0] if c else {}
    return {k: float(v) for k, v in c.items()
            if isinstance(v, (int, float))}


def _drop_batch(tree):
    """B=1 cells (long_500k) cannot shard the batch dim: replicate it."""
    from jax.sharding import PartitionSpec as P

    def fix(spec):
        return P(*[None if el == "batch" else el for el in spec])

    return jax.tree.map(fix, tree, is_leaf=lambda x: isinstance(
        x, jax.sharding.PartitionSpec))


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             microbatches=None, seq_parallel=None, opt_dtype=None,
             accum_dtype=None, capacity_factor=None, remat_policy=None,
             keep_hlo: bool = False) -> dict:
    t_start = time.time()
    shape = SHAPES[shape_name]
    opts = cell_opts.cell_options(arch, shape_name, microbatches,
                                  seq_parallel, opt_dtype)
    if accum_dtype is not None:
        import dataclasses as _dc
        opts = _dc.replace(opts, train=_dc.replace(
            opts.train, accum_dtype=accum_dtype))
    if capacity_factor is not None:
        from repro.models import layers as _L
        _L.set_moe_capacity_factor(capacity_factor)
    if remat_policy is not None:
        from repro.models import layers as _L
        _L.set_remat_policy(remat_policy)
    cfg = get_arch(arch)
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    baxes = mesh_lib.batch_axes(multi_pod)
    sharding.set_mesh(mesh, batch_axes=baxes, model_axis="model",
                      fsdp_axis="data", seq_parallel=opts.seq_parallel)
    model = build_model(cfg)
    batch_shapes, batch_lspecs = input_specs(cfg, shape)
    if shape.global_batch == 1:
        batch_lspecs = _drop_batch(batch_lspecs)
    batch_ns = mesh_lib.named_tree(batch_lspecs, mesh, multi_pod)
    param_ns = mesh_lib.named_tree(model.param_specs(), mesh, multi_pod)

    if shape.kind == "train":
        state_shapes = jax.eval_shape(
            lambda: init_train_state(model, jax.random.PRNGKey(0), opts.opt,
                                     opts.train))
        state_ns = mesh_lib.named_tree(
            train_state_specs(model, opts.train), mesh, multi_pod)
        step = build_train_step(model, opts.opt, opts.train)
        jfn = jax.jit(step, in_shardings=(state_ns, batch_ns),
                      out_shardings=(state_ns, None), donate_argnums=0)
        t0 = time.time()
        lowered = jfn.lower(state_shapes, batch_shapes)
    elif shape.kind == "prefill":
        params_shapes = jax.eval_shape(
            lambda: model.init_params(jax.random.PRNGKey(0)))
        fn = lambda params, batch: model.prefill(params, batch,
                                                 max_len=shape.seq_len)
        jfn = jax.jit(fn, in_shardings=(param_ns, batch_ns))
        t0 = time.time()
        lowered = jfn.lower(params_shapes, batch_shapes)
    else:   # decode
        params_shapes = jax.eval_shape(
            lambda: model.init_params(jax.random.PRNGKey(0)))
        cache_shapes, cache_lspecs = model.cache_spec(
            shape.global_batch, shape.seq_len,
            seq_axes=opts.cache_seq_axes)
        if shape.global_batch == 1:
            cache_lspecs = _drop_batch(cache_lspecs)
        cache_ns = mesh_lib.named_tree(cache_lspecs, mesh, multi_pod)
        jfn = jax.jit(model.decode_step,
                      in_shardings=(param_ns, batch_ns, cache_ns),
                      donate_argnums=2)
        t0 = time.time()
        lowered = jfn.lower(params_shapes, batch_shapes, cache_shapes)
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    hlo = compiled.as_text()
    record = {
        "arch": arch,
        "shape": shape_name,
        "kind": shape.kind,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "num_devices": mesh.devices.size,
        "options": {
            "microbatches": opts.train.microbatches,
            "seq_parallel": opts.seq_parallel,
            "opt_state_dtype": opts.opt.state_dtype,
            "accum_dtype": opts.train.accum_dtype,
            "capacity_factor": capacity_factor,
            "remat_policy": remat_policy or "nothing",
            "cache_seq_axes": list(opts.cache_seq_axes),
        },
        "num_params": cfg.num_params(),
        "num_active_params": cfg.num_active_params(),
        "lower_seconds": round(t_lower, 1),
        "compile_seconds": round(t_compile, 1),
        "total_seconds": round(time.time() - t_start, 1),
        "memory_analysis": _mem_dict(compiled),
        "cost_analysis": _cost_dict(compiled),
        # Trip-count-weighted re-analysis of the partitioned module (XLA's
        # own cost_analysis visits while bodies once -- see hlo_cost.py).
        "hlo_cost": hlo_cost.analyze(hlo),
        "collectives": parse_collective_bytes(hlo),
        "hlo_bytes": len(hlo),
    }
    if keep_hlo:
        record["hlo_text"] = hlo
    del compiled, lowered, hlo
    return record


def main() -> None:
    ap = argparse.ArgumentParser(description="Multi-pod dry-run")
    ap.add_argument("--arch", default=None, help="single arch (default: all)")
    ap.add_argument("--shape", default=None, help="single shape (default: all)")
    ap.add_argument("--mesh", default="both", choices=["single", "multi",
                                                       "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--seq-parallel", type=int, default=None,
                    help="0/1 override")
    ap.add_argument("--opt-dtype", default=None)
    ap.add_argument("--accum-dtype", default=None)
    ap.add_argument("--capacity-factor", type=float, default=None)
    ap.add_argument("--remat-policy", default=None,
                    choices=["nothing", "dots"])
    ap.add_argument("--tag", default="", help="suffix for output files")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    cells = runnable_cells()
    if args.arch:
        cells = [c for c in cells if c[0] == args.arch]
    if args.shape:
        cells = [c for c in cells if c[1] == args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    results, failures = [], []
    for arch, shape_name in cells:
        for mp in meshes:
            tagname = f"{arch}__{shape_name}__{'multi' if mp else 'single'}"
            if args.tag:
                tagname += f"__{args.tag}"
            out_path = os.path.join(args.out, tagname + ".json")
            print(f"=== {tagname} ===", flush=True)
            try:
                sp = None if args.seq_parallel is None else bool(
                    args.seq_parallel)
                rec = run_cell(arch, shape_name, mp,
                               microbatches=args.microbatches,
                               seq_parallel=sp, opt_dtype=args.opt_dtype,
                               accum_dtype=args.accum_dtype,
                               capacity_factor=args.capacity_factor,
                               remat_policy=args.remat_policy)
                with open(out_path, "w") as f:
                    json.dump(rec, f, indent=1)
                hc = rec["hlo_cost"]
                print(f"    ok: compile={rec['compile_seconds']}s "
                      f"flops/dev={hc['flops_per_device']:.3e} "
                      f"bytes/dev={hc['bytes_per_device']:.3e} "
                      f"coll/dev={hc['collective_bytes_per_device']:.3e}B",
                      flush=True)
                results.append(rec)
            except Exception as e:
                traceback.print_exc()
                failures.append((tagname, f"{type(e).__name__}: {e}"))
                with open(out_path + ".failed", "w") as f:
                    f.write(traceback.format_exc())

    print(f"\n==== dry-run done: {len(results)} ok, {len(failures)} failed")
    for name, err in failures:
        print(f"  FAIL {name}: {err[:300]}")
    for arch, shape_name, why in skipped_cells():
        print(f"  SKIP {arch} x {shape_name}: {why} (see DESIGN.md)")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
