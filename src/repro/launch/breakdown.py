import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

os.environ.setdefault("TF_CPP_MIN_LOG_LEVEL", "2")

"""Per-op flop / collective attribution for one dry-run cell (perf tooling).

Usage: PYTHONPATH=src python -m repro.launch.breakdown --arch X --shape Y
           [--collectives] [--microbatches N] ...
"""

import argparse    # noqa: E402
import re          # noqa: E402

from repro.launch import hlo_cost            # noqa: E402
from repro.launch.dryrun import run_cell     # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--seq-parallel", type=int, default=None)
    ap.add_argument("--accum-dtype", default=None)
    ap.add_argument("--capacity-factor", type=float, default=None)
    ap.add_argument("--remat-policy", default=None)
    ap.add_argument("--top", type=int, default=15)
    args = ap.parse_args()

    sp = None if args.seq_parallel is None else bool(args.seq_parallel)
    rec = run_cell(args.arch, args.shape, args.multi_pod,
                   microbatches=args.microbatches, seq_parallel=sp,
                   accum_dtype=args.accum_dtype,
                   capacity_factor=args.capacity_factor,
                   remat_policy=args.remat_policy, keep_hlo=True)
    hlo = rec.pop("hlo_text")
    hc = rec["hlo_cost"]
    print(f"flops/dev={hc['flops_per_device']:.3e} "
          f"bytes/dev={hc['bytes_per_device']:.3e} "
          f"coll/dev={hc['collective_bytes_per_device']:.3e}")

    comps = hlo_cost.parse_computations(hlo)
    mult = hlo_cost._multipliers(comps)
    dots, colls = [], []
    for comp, ops in comps.items():
        m = mult.get(comp, 0.0)
        if m == 0:
            continue
        symbols = hlo_cost._symbol_table(ops)
        for op in ops:
            meta = re.search(r'op_name="([^"]*)"', op.line)
            name = (meta.group(1) if meta else "")[-72:]
            if op.opcode == "dot":
                dots.append((m * hlo_cost._dot_flops(op, symbols),
                             op.type_str[:30], f"x{m:.0f}", name))
            base = op.opcode.replace("-start", "")
            if base in hlo_cost._COLLECTIVES:
                colls.append((m * hlo_cost._shape_bytes(op.type_str), base,
                              op.type_str[:40], f"x{m:.0f}", name))
    print(f"\n== top dots (total {sum(d[0] for d in dots):.3e} flops/dev):")
    for d in sorted(dots, reverse=True)[:args.top]:
        print(f"  {d[0]:.2e} {d[1]:32s} {d[2]:5s} {d[3]}")
    print(f"\n== top collectives (total "
          f"{sum(c[0] for c in colls):.3e} bytes/dev):")
    for c in sorted(colls, reverse=True)[:args.top]:
        print(f"  {c[0]:.2e} {c[1]:18s} {c[2]:42s} {c[3]:5s} {c[4]}")


if __name__ == "__main__":
    main()
