"""Process-parallel shard execution for the routing plane.

:class:`repro.engine.router.FleetRouter` runs its shards inline — each
shard is an independent :class:`~repro.engine.fleet.FleetEngine` with
its own scheduler and packed plane, so nothing stops them draining in
parallel.  This module supplies the parallel runner: a
:class:`ShardHost` is one shard living in its own OS process (spawned,
so no fork-after-JAX hazards), driven over a pipe by a tiny command
protocol, and a :class:`ProcessShardSet` is N hosts behind the same
consistent-hash placement the inline router uses
(:func:`repro.engine.router.shard_ids_for` + the
:class:`~repro.engine.placement.PartitionDirectory`), presenting the
same submit/drain/stats EventSink surface.  ``drain`` is split-phase —
every host is told to drain before any is waited on — so shard work
overlaps across cores.  (The accelerator-resident alternative is to lay
shards over JAX devices with :mod:`repro.launch.mesh`; processes are
the portable default.)

Tenant engines are built *inside* the worker from picklable zero-arg
factories (module-level functions / :func:`functools.partial`), so the
parent never pays for — or shares — shard state.  Live migration works
across processes too: :meth:`ProcessShardSet.migrate_tenant` pickles
the detached engine (its trace, StateMatrix plane, pending deltas and
micro-move ledger are all ordinary state on the object) through the
parent to the target host, same finish-or-transplant semantics as the
inline router.
"""
from __future__ import annotations

import multiprocessing as mp
import traceback
from typing import Callable, Dict, List, Mapping, Optional

from repro.engine.placement import HashRing, PartitionDirectory
from repro.engine.scheduler import SchedulerSpec
from repro.engine.router import shard_ids_for


def _shard_worker(conn, factories: Dict[str, Callable],
                  spec: SchedulerSpec, name: str,
                  incremental: Optional[bool]) -> None:
    """Worker main loop: build the shard fleet, serve commands until EOF."""
    from repro.engine.fleet import FleetEngine

    try:
        tenants = {tid: factory() for tid, factory in factories.items()}
        fleet = FleetEngine(tenants, spec.build(), name=name,
                            incremental=incremental)
        conn.send(("ok", None))
    except BaseException:
        conn.send(("err", traceback.format_exc()))
        return
    while True:
        try:
            cmd, payload = conn.recv()
        except EOFError:
            return
        try:
            if cmd == "submit_many":
                for ev in payload:
                    fleet.submit(ev)
                result = len(payload)
            elif cmd == "drain":
                result = fleet.drain(**payload)
            elif cmd == "result":
                result = fleet.result(payload)
            elif cmd == "stats":
                result = fleet.stats()
            elif cmd == "queue_depth":
                result = fleet.queue_depth
            elif cmd == "migrate_out":
                inbox = fleet.take_inbox(payload)
                engine = fleet.remove_tenant(payload)
                result = (engine, inbox)
            elif cmd == "migrate_in":
                tid, engine, inbox = payload
                fleet.add_tenant(tid, engine)
                for ev in inbox:
                    fleet.submit(ev)
                result = None
            elif cmd == "close":
                conn.send(("ok", None))
                return
            else:
                raise ValueError(f"unknown shard command {cmd!r}")
            conn.send(("ok", result))
        except BaseException:
            conn.send(("err", traceback.format_exc()))


class ShardHostError(RuntimeError):
    """A shard worker raised; carries the worker-side traceback."""


class ShardHost:
    """One fleet shard behind a spawned worker process.

    Submits buffer in the parent and flush with the next drain (one
    pipe round trip per drain, not per event).  All calls are
    synchronous except the :meth:`start_drain` / :meth:`finish_drain`
    pair, which :class:`ProcessShardSet` uses to overlap shard drains.
    """

    def __init__(self, shard_id: str, factories: Mapping[str, Callable],
                 spec: SchedulerSpec, name: Optional[str] = None,
                 incremental: Optional[bool] = None,
                 mp_context: str = "spawn"):
        self.shard_id = shard_id
        ctx = mp.get_context(mp_context)
        self._conn, child = ctx.Pipe()
        self._proc = ctx.Process(
            target=_shard_worker,
            args=(child, dict(factories), spec, name or shard_id,
                  incremental),
            daemon=True)
        self._proc.start()
        child.close()
        self._outbox: List = []
        self._busy = False          # a start_drain awaiting finish_drain
        self._recv()                # worker construction handshake

    def _recv(self):
        status, payload = self._conn.recv()
        if status != "ok":
            raise ShardHostError(
                f"shard {self.shard_id!r} worker failed:\n{payload}")
        return payload

    def _call(self, cmd: str, payload=None):
        if self._busy:
            raise RuntimeError("finish_drain() the in-flight drain first")
        self._conn.send((cmd, payload))
        return self._recv()

    # -- EventSink-ish surface -----------------------------------------
    def submit(self, event) -> None:
        self._outbox.append(event)

    @property
    def queue_depth(self) -> int:
        return len(self._outbox) + self._call("queue_depth")

    def flush_submits(self) -> int:
        if not self._outbox:
            return 0
        out, self._outbox = self._outbox, []
        return self._call("submit_many", out)

    def start_drain(self, **kwargs) -> None:
        """Flush buffered submits and tell the worker to drain (async)."""
        self.flush_submits()
        self._conn.send(("drain", kwargs))
        self._busy = True

    def finish_drain(self) -> int:
        self._busy = False
        return self._recv()

    def drain(self, **kwargs) -> int:
        self.start_drain(**kwargs)
        return self.finish_drain()

    def result(self, name: Optional[str] = None):
        return self._call("result", name)

    def stats(self) -> dict:
        return self._call("stats")

    def migrate_out(self, tenant_id: str):
        return self._call("migrate_out", tenant_id)

    def migrate_in(self, tenant_id: str, engine, inbox) -> None:
        self._call("migrate_in", (tenant_id, engine, inbox))

    def close(self) -> None:
        if self._proc.is_alive():
            try:
                self._call("close")
            except (ShardHostError, OSError, EOFError):
                pass
            self._proc.join(timeout=10)
        self._conn.close()


class ProcessShardSet:
    """N process-resident shards behind the router's placement.

    Same consistent-hash tenant→shard mapping as an inline
    :class:`repro.engine.router.FleetRouter` with the same shard count
    and ``replicas`` — the two agree on every tenant's home, so a
    deployment can switch runners without a placement migration.
    Context-manage it (or call :meth:`close`) to reap the workers.
    """

    def __init__(self, factories: Mapping[str, Callable],
                 num_shards: int = 2,
                 scheduler: Optional[SchedulerSpec] = None,
                 name: str = "procset",
                 replicas: int = 64,
                 incremental: Optional[bool] = None,
                 mp_context: str = "spawn"):
        if not factories:
            raise ValueError("a shard set needs at least one tenant factory")
        self.name = name
        spec = scheduler or SchedulerSpec.unlimited()
        self.ring = HashRing(shard_ids_for(num_shards), replicas=replicas)
        self.directory = PartitionDirectory(self.ring)
        by_shard: Dict[str, Dict[str, Callable]] = {
            sid: {} for sid in self.ring.shard_ids}
        for tid, factory in factories.items():
            by_shard[self.directory.lookup(tid)][tid] = factory
        self._hosts: Dict[str, ShardHost] = {}
        try:
            for sid in self.ring.shard_ids:
                self._hosts[sid] = ShardHost(
                    sid, by_shard[sid], spec, name=f"{name}/{sid}",
                    incremental=incremental, mp_context=mp_context)
        except BaseException:
            self.close()
            raise
        self._known = set(factories)
        self.migrations = 0

    def __enter__(self) -> "ProcessShardSet":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    @property
    def shard_ids(self) -> List[str]:
        return self.ring.shard_ids

    def shard_of(self, tenant_id: str) -> str:
        if tenant_id not in self._known:
            raise KeyError(f"unknown tenant {tenant_id!r}")
        return self.directory.lookup(tenant_id)

    # -- EventSink surface ---------------------------------------------
    def submit(self, event) -> None:
        from repro.core import workload as wl
        ev = wl.as_event(event)
        self._hosts[self.shard_of(ev.tenant_id)].submit(ev)

    @property
    def queue_depth(self) -> int:
        return sum(h.queue_depth for h in self._hosts.values())

    def drain(self, **kwargs) -> int:
        """Drain all shards concurrently (split-phase over the workers)."""
        kwargs.pop("collect", None)     # per-event observations stay local
        for sid in self.ring.shard_ids:
            self._hosts[sid].start_drain(**kwargs)
        return sum(self._hosts[sid].finish_drain()
                   for sid in self.ring.shard_ids)

    def stats(self) -> dict:
        return {
            "name": self.name,
            "num_shards": len(self._hosts),
            "tenants": len(self._known),
            "migrations": self.migrations,
            "shards": {sid: self._hosts[sid].stats()
                       for sid in self.ring.shard_ids},
        }

    def result(self, name: Optional[str] = None):
        from repro.engine.fleet import FleetResult
        per_tenant = {}
        ticks = deferred = deferred_ticks = 0
        shard_stats = {}
        sched_name = ""
        for sid in self.ring.shard_ids:
            r = self._hosts[sid].result()
            per_tenant.update(r.per_tenant)
            ticks += r.ticks
            deferred += r.swaps_deferred
            deferred_ticks += r.deferred_ticks
            shard_stats[sid] = r.scheduler_stats
            sched_name = r.scheduler
        return FleetResult(name=name or self.name, scheduler=sched_name,
                           per_tenant=per_tenant, ticks=ticks,
                           swaps_deferred=deferred,
                           deferred_ticks=deferred_ticks,
                           scheduler_stats={"shards": shard_stats})

    def migrate_tenant(self, tenant_id: str, target_shard: str) -> bool:
        """Engine + queued events, pickled source → parent → target."""
        if target_shard not in self._hosts:
            raise KeyError(f"unknown shard {target_shard!r}")
        source_shard = self.shard_of(tenant_id)
        if source_shard == target_shard:
            return False
        # Parent-side buffered submits must reach the worker inbox first,
        # or migrate_out would miss them.
        self._hosts[source_shard].flush_submits()
        engine, inbox = self._hosts[source_shard].migrate_out(tenant_id)
        self._hosts[target_shard].migrate_in(tenant_id, engine, inbox)
        self.directory.assign(tenant_id, target_shard)
        self.migrations += 1
        return True

    def close(self) -> None:
        for host in self._hosts.values():
            host.close()
        self._hosts = {}


__all__ = ["ProcessShardSet", "ShardHost", "ShardHostError"]
