"""Trip-count-weighted cost extraction from partitioned, optimized HLO.

XLA's built-in ``compiled.cost_analysis()`` visits every while body exactly
once, which silently undercounts scan-heavy programs (layer scans, microbatch
accumulation, flash-attention block loops) by orders of magnitude.  This
module re-derives the roofline inputs directly from ``compiled.as_text()``:

* every computation gets a *multiplier* = product of ``known_trip_count`` of
  the while ops (transitively) calling it -- XLA:CPU stamps
  ``backend_config={"known_trip_count":{"n":...}}`` on scan-derived whiles;
* FLOPs  = sum over dot/convolution ops of 2*prod(out)*contraction x mult
  (elementwise flops are ignored -- matmuls dominate by >100x);
* bytes  = sum over materializing ops (post-fusion kernel launches) of
  operand+output bytes x mult -- the right granularity for HBM traffic since
  fusions are single kernels in the optimized module;
* collective bytes by type (all-gather / all-reduce / reduce-scatter /
  all-to-all / collective-permute), output-shape bytes x mult.

All shapes in the post-SPMD module are PER-DEVICE.
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(" + "|".join(_DTYPE_BYTES) + r")\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s+=\s+")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALL_ATTR_RE = re.compile(r"(?:calls|to_apply|condition|body)=%([\w\.\-]+)")

_SKIP_BYTES_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
                   "bitcast", "after-all", "while", "conditional", "call",
                   "iota", "partition-id", "replica-id"}
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _shape_elems(text: str) -> int:
    m = _SHAPE_RE.search(text)
    if not m:
        return 0
    n = 1
    for d in m.group(2).split(","):
        if d:
            n *= int(d)
    return n


def _shape_dims(text: str) -> List[int]:
    m = _SHAPE_RE.search(text)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


class _Op:
    __slots__ = ("name", "type_str", "opcode", "rest", "line")

    def __init__(self, name, type_str, opcode, rest, line):
        self.name = name
        self.type_str = type_str
        self.opcode = opcode
        self.rest = rest
        self.line = line


def _parse_op(line: str) -> Optional[_Op]:
    m = _DEF_RE.match(line)
    if not m:
        return None
    name = m.group(1)
    rest = line[m.end():]
    # Type is either "(tuple, ...)" or a single token; opcode is the word
    # right before the next "(".
    if rest.startswith("("):
        depth = 0
        for i, ch in enumerate(rest):
            depth += ch == "("
            depth -= ch == ")"
            if depth == 0:
                break
        type_str = rest[:i + 1]
        tail = rest[i + 1:].lstrip()
    else:
        sp = rest.find(" ")
        type_str = rest[:sp]
        tail = rest[sp + 1:].lstrip()
    par = tail.find("(")
    if par < 0:
        return None
    opcode = tail[:par].strip()
    return _Op(name, type_str, opcode, tail[par:], line)


def parse_computations(hlo: str) -> Dict[str, List[_Op]]:
    comps: Dict[str, List[_Op]] = {}
    current = None
    for line in hlo.splitlines():
        if line.startswith("ENTRY") or (line.startswith("%")
                                        and line.rstrip().endswith("{")):
            m = re.match(r"(?:ENTRY\s+)?%([\w\.\-]+)", line)
            current = m.group(1) if m else None
            if line.startswith("ENTRY"):
                current = "__entry__:" + (current or "")
            comps[current] = []
        elif line.startswith("}"):
            current = None
        elif current is not None:
            op = _parse_op(line)
            if op is not None:
                comps[current].append(op)
    return comps


def _multipliers(comps: Dict[str, List[_Op]]) -> Dict[str, float]:
    entry = next((k for k in comps if k.startswith("__entry__:")), None)
    mult: Dict[str, float] = {k: 0.0 for k in comps}
    if entry is None:
        return {k: 1.0 for k in comps}
    mult[entry] = 1.0
    # Propagate: iterate to fixpoint (call graph is a DAG; few passes enough).
    for _ in range(12):
        changed = False
        for comp, ops in comps.items():
            m = mult.get(comp, 0.0)
            if m == 0.0:
                continue
            for op in ops:
                trip = 1.0
                if op.opcode == "while":
                    t = _TRIP_RE.search(op.line)
                    trip = float(t.group(1)) if t else 1.0
                for callee in _CALL_ATTR_RE.findall(op.line):
                    new = m * (trip if op.opcode == "while" else 1.0)
                    if new > mult.get(callee, 0.0):
                        mult[callee] = new
                        changed = True
        if not changed:
            break
    return mult


def _symbol_table(ops: List[_Op]) -> Dict[str, str]:
    return {op.name: op.type_str for op in ops}


def _dot_flops(op: _Op, symbols: Dict[str, str]) -> float:
    out_elems = _shape_elems(op.type_str)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.line)
    contract = 1
    operands = re.findall(r"%([\w\.\-]+)", op.rest.split("),")[0])
    if m and operands:
        lhs_shape = _shape_dims(symbols.get(operands[0], ""))
        for d in m.group(1).split(","):
            if d and int(d) < len(lhs_shape):
                contract *= lhs_shape[int(d)]
    return 2.0 * out_elems * contract


def _conv_flops(op: _Op, symbols: Dict[str, str]) -> float:
    out_elems = _shape_elems(op.type_str)
    operands = re.findall(r"%([\w\.\-]+)", op.rest.split("),")[0])
    kernel = _shape_dims(symbols.get(operands[1], "")) if len(operands) > 1 \
        else []
    if not kernel:
        return 2.0 * out_elems
    out_ch = kernel[-1]
    return 2.0 * out_elems * max(1, int(
        (1.0 * _prod(kernel)) / max(out_ch, 1)))


def _prod(xs):
    p = 1
    for x in xs:
        p *= x
    return p


_SLICING_OPS = {"dynamic-slice", "slice", "gather"}


def _fusion_input_bytes(ops: List[_Op]) -> Dict[int, int]:
    """Effective bytes read per parameter index of a fused computation.

    A parameter consumed ONLY by slicing ops (dynamic-slice / slice / gather)
    costs its slices' output bytes, not the full array -- this is what makes
    scan-sliced weight stacks and KV caches count correctly per iteration.
    """
    param_names: Dict[str, int] = {}
    for op in ops:
        if op.opcode == "parameter":
            idx = int(re.search(r"parameter\((\d+)\)", op.line).group(1))
            param_names[op.name] = idx
    sliced_bytes: Dict[int, int] = {}
    full_needed: Dict[int, bool] = {i: False for i in param_names.values()}
    consumed: Dict[int, bool] = {i: False for i in param_names.values()}
    for op in ops:
        if op.opcode == "parameter":
            continue
        operands = re.findall(r"%([\w\.\-]+)",
                              op.rest.split("metadata=")[0])
        for operand in operands:
            if operand not in param_names:
                continue
            idx = param_names[operand]
            consumed[idx] = True
            if op.opcode in _SLICING_OPS:
                sliced_bytes[idx] = sliced_bytes.get(idx, 0) + _shape_bytes(
                    op.type_str)
            elif op.opcode == "dynamic-update-slice":
                # reads the update operand + writes in place; charge the
                # smaller update size, not the full buffer
                sliced_bytes[idx] = sliced_bytes.get(idx, 0)
            else:
                full_needed[idx] = True
    out: Dict[int, int] = {}
    for name, idx in param_names.items():
        if full_needed[idx] or not consumed[idx]:
            out[idx] = -1            # caller should charge full operand bytes
        else:
            out[idx] = sliced_bytes.get(idx, 0)
    return out


def _fusion_output_bytes(ops: List[_Op]) -> int:
    """Effective bytes written by a fused computation: a root
    dynamic-update-slice writes only its update region, not the buffer."""
    for op in ops:
        if op.line.lstrip().startswith("ROOT"):
            if op.opcode == "dynamic-update-slice":
                operands = re.findall(r"%([\w\.\-]+)",
                                      op.rest.split("metadata=")[0])
                symbols = _symbol_table(ops)
                if len(operands) > 1:
                    return _shape_bytes(symbols.get(operands[1], ""))
            return -1                # caller uses the call-site output type
    return -1


def analyze(hlo: str) -> Dict:
    comps = parse_computations(hlo)
    mult = _multipliers(comps)
    fusion_inputs = {name: _fusion_input_bytes(ops)
                     for name, ops in comps.items()}
    fusion_outputs = {name: _fusion_output_bytes(ops)
                      for name, ops in comps.items()}
    # Computations reached via fusion `calls=` / reduce `to_apply=` are
    # inlined kernels: their internals never touch HBM independently.  Bytes
    # are charged only at "control" level (entry + while bodies/conds).
    inlined = set()
    for ops in comps.values():
        for op in ops:
            if op.opcode != "while":
                for callee in _CALL_ATTR_RE.findall(op.line):
                    inlined.add(callee)
    flops = 0.0
    bytes_accessed = 0.0
    coll_bytes: Dict[str, float] = {}
    coll_counts: Dict[str, float] = {}
    for comp, ops in comps.items():
        m = mult.get(comp, 0.0)
        if m == 0.0:
            continue
        symbols = _symbol_table(ops)
        count_bytes = comp not in inlined
        for op in ops:
            base = op.opcode.replace("-start", "")
            if op.opcode == "dot":
                flops += m * _dot_flops(op, symbols)
            elif op.opcode == "convolution":
                flops += m * _conv_flops(op, symbols)
            if base in _COLLECTIVES and count_bytes:
                b = m * _shape_bytes(op.type_str)
                coll_bytes[base] = coll_bytes.get(base, 0.0) + b
                coll_counts[base] = coll_counts.get(base, 0.0) + m
            if not count_bytes:
                continue
            if op.opcode in _SKIP_BYTES_OPS or op.opcode.endswith("-done"):
                continue
            operands = re.findall(r"%([\w\.\-]+)",
                                  op.rest.split("metadata=")[0])
            callee = None
            if op.opcode == "fusion":
                mm = re.search(r"calls=%([\w\.\-]+)", op.line)
                callee = mm.group(1) if mm else None
            if op.opcode == "dynamic-update-slice":
                upd = (_shape_bytes(symbols.get(operands[1], ""))
                       if len(operands) > 1 else 0)
                b = 2 * upd          # read update + write region in place
            elif op.opcode in ("dynamic-slice", "slice", "gather"):
                b = 2 * _shape_bytes(op.type_str)
            else:
                eff_out = fusion_outputs.get(callee, -1) if callee else -1
                b = eff_out if eff_out >= 0 else _shape_bytes(op.type_str)
                per_param = fusion_inputs.get(callee, {}) if callee else {}
                for i, operand in enumerate(operands):
                    eff = per_param.get(i, -1)
                    if eff >= 0:
                        b += eff
                    else:
                        b += _shape_bytes(symbols.get(operand, ""))
            bytes_accessed += m * b
    return {
        "flops_per_device": flops,
        "bytes_per_device": bytes_accessed,
        "collective_bytes_per_device": sum(coll_bytes.values()),
        "collective_bytes_by_type": coll_bytes,
        "collective_counts_by_type": coll_counts,
        "num_computations": len(comps),
    }
