"""Roofline analysis over the dry-run records (§Roofline of EXPERIMENTS.md).

Per (arch x shape x mesh) cell, derives the three per-device roofline terms
from the trip-count-weighted HLO analysis (hlo_cost.py):

    compute    = flops_per_device     / PEAK_FLOPS      (197 TFLOP/s bf16)
    memory     = bytes_per_device     / HBM_BW          (819 GB/s)
    collective = coll_bytes_per_device/ LINK_BW         (~50 GB/s/link ICI)

plus MODEL_FLOPS (6*N*D train / 2*N*D inference, N = active params) and the
useful-compute ratio MODEL_FLOPS / (HLO flops x chips), which catches remat
recompute, MoE capacity waste, padding, and replicated compute.

Caveat recorded in every report: the module is compiled by XLA:CPU, which
promotes bf16 compute to f32 (extra converts/copies) -- the memory term is
therefore an upper bound, up to ~2x pessimistic vs a TPU build.

Usage:  PYTHONPATH=src python -m repro.launch.roofline \
            --dryrun experiments/dryrun --out experiments/roofline
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List

PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link

from repro.configs.base import SHAPES, get_arch  # noqa: E402


def model_flops(arch: str, shape_name: str) -> float:
    """Analytic useful FLOPs per step (global, forward(+backward))."""
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    n_active = cfg.num_active_params()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence; attention reads the KV cache but does
    # negligible extra matmul FLOPs relative to 2N.
    return 2.0 * n_active * shape.global_batch


def ideal_bytes(arch: str, shape_name: str, opt_dtype: str = "float32"
                ) -> float:
    """Analytic minimal HBM traffic per step (global bytes).

    train:   params read twice (fwd+bwd) + grad write + optimizer m/v
             read+write + param write.
    prefill: params read + KV cache write.
    decode:  active params read + KV cache read (the serving floor).
    """
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    n = cfg.num_params()
    n_active = cfg.num_active_params()
    opt_b = 2 if opt_dtype == "bfloat16" else 4
    kv_per_tok = 2 * cfg.n_kv_heads * cfg.head_dim * 2   # k+v bf16
    n_attn_layers = (0 if cfg.family == "ssm" else
                     (cfg.n_layers // cfg.attn_every if cfg.family == "hybrid"
                      else cfg.n_layers))
    if shape.kind == "train":
        return n * 2 * 3 + n * 4 + n * opt_b * 4          # bf16 p, f32 grads
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return n_active * 2 + tokens * kv_per_tok * n_attn_layers
    kv_read = shape.global_batch * shape.seq_len * kv_per_tok * n_attn_layers
    state = 0.0
    if cfg.family in ("ssm", "hybrid") and cfg.ssm is not None:
        d_in = cfg.ssm.expand * cfg.d_model
        state = (shape.global_batch * cfg.n_layers
                 * (d_in // cfg.ssm.head_dim) * cfg.ssm.head_dim
                 * cfg.ssm.d_state * 4)
    if cfg.family == "ssm":
        dh = cfg.rwkv_head_dim
        state = (shape.global_batch * cfg.n_layers
                 * (cfg.d_model // dh) * dh * dh * 4)
    return n_active * 2 + kv_read + state


def analyze_record(rec: Dict) -> Dict:
    hc = rec["hlo_cost"]
    chips = rec["num_devices"]
    compute_s = hc["flops_per_device"] / PEAK_FLOPS
    memory_s = hc["bytes_per_device"] / HBM_BW
    coll_s = hc["collective_bytes_per_device"] / LINK_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec["arch"], rec["shape"])
    hlo_flops_global = hc["flops_per_device"] * chips
    useful_ratio = mf / max(hlo_flops_global, 1.0)
    opt_dtype = rec.get("options", {}).get("opt_state_dtype", "float32")
    ib = ideal_bytes(rec["arch"], rec["shape"], opt_dtype)
    # The achievable step-time floor is the max of the compute ideal and the
    # memory ideal; roofline fraction = floor / modeled dominant term.
    ideal_s = max(mf / chips / PEAK_FLOPS, ib / chips / HBM_BW)
    roofline_fraction = ideal_s / max(max(terms.values()), 1e-12)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "kind": rec["kind"],
        "compute_s": compute_s, "memory_s": memory_s, "collective_s": coll_s,
        "dominant": dominant,
        "model_flops": mf,
        "ideal_bytes": ib,
        "ideal_s": ideal_s,
        "hlo_flops_global": hlo_flops_global,
        "useful_ratio": useful_ratio,
        "roofline_fraction": roofline_fraction,
        "collective_by_type": hc["collective_bytes_by_type"],
        "options": rec.get("options", {}),
        "memory_analysis": rec.get("memory_analysis", {}),
        "compile_seconds": rec.get("compile_seconds"),
    }


_NOTES = {
    "compute": ("dominant term is MXU compute; lower it by cutting remat "
                "recompute (useful_ratio < 0.75 means recompute/waste) or "
                "removing padded/replicated matmul work"),
    "memory": ("dominant term is HBM traffic; lower it with bf16-resident "
               "states, fused elementwise chains, larger attention blocks "
               "(fewer re-reads), or fewer optimizer passes"),
    "collective": ("dominant term is interconnect; lower it by re-sharding "
                   "to cut all-gathers (FSDP prefetch), overlapping "
                   "collectives with compute, or compressing gradients"),
}


def to_markdown(rows: List[Dict]) -> str:
    out = ["| arch | shape | mesh | compute s | memory s | collective s | "
           "dominant | MODEL_FLOPS | useful ratio | roofline frac |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compute_s']:.3f} | {r['memory_s']:.3f} "
            f"| {r['collective_s']:.3f} | **{r['dominant']}** "
            f"| {r['model_flops']:.2e} | {r['useful_ratio']:.2f} "
            f"| {r['roofline_fraction']:.3f} |")
    out.append("")
    out.append("Bottleneck notes (per dominant term):")
    for k, v in _NOTES.items():
        out.append(f"- **{k}**: {v}.")
    return "\n".join(out)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", default="experiments/dryrun")
    ap.add_argument("--out", default="experiments/roofline")
    ap.add_argument("--mesh", default="single", choices=["single", "multi",
                                                         "both"])
    args = ap.parse_args()
    rows = []
    for path in sorted(glob.glob(os.path.join(args.dryrun, "*.json"))):
        if path.endswith(".failed"):
            continue
        with open(path) as f:
            rec = json.load(f)
        if args.mesh != "both":
            want = "16x16" if args.mesh == "single" else "2x16x16"
            if rec["mesh"] != want:
                continue
        rows.append(analyze_record(rec))
    os.makedirs(args.out, exist_ok=True)
    with open(os.path.join(args.out, f"roofline_{args.mesh}.json"), "w") as f:
        json.dump(rows, f, indent=1)
    md = to_markdown(rows)
    with open(os.path.join(args.out, f"roofline_{args.mesh}.md"), "w") as f:
        f.write(md)
    print(md)


if __name__ == "__main__":
    main()
