"""repro: OREO (online data-layout reorganization with worst-case
guarantees) integrated as the data-pipeline layout optimizer of a
production-grade multi-pod JAX training/serving framework."""
__version__ = "1.0.0"
