"""Columnar partition store: the physical layer under a data layout.

Materializes a layout (BID assignment) as one compressed file per partition
plus a metadata manifest -- the same structure the paper's Spark integration
uses (BID column + partition-level zone maps).  ``scan`` reads only the
partitions a query's predicates cannot skip; ``reorganize`` rewrites every
partition under a new layout (the alpha-cost operation measured in Table I).
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import time
from typing import Tuple

import numpy as np

from repro.core import layouts as L
from repro.core import workload as wl


@dataclasses.dataclass
class ScanStats:
    partitions_read: int
    partitions_total: int
    rows_read: int
    seconds: float


class PartitionStore:
    """On-disk partitioned table with zone-map metadata."""

    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)

    # ------------------------------------------------------------------
    def write(self, data: np.ndarray, layout: L.Layout,
              compress: bool = True) -> float:
        """Full reorganization: route rows, rewrite all partition files.
        Returns seconds taken (the measured reorg cost)."""
        t0 = time.time()
        assignment = (layout.route(data) if layout.route is not None
                      else np.zeros(len(data), np.int64))
        tmp = self.root + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        k = layout.num_partitions
        mins, maxs, rows = [], [], []
        save = np.savez_compressed if compress else np.savez
        for p in range(k):
            chunk = data[assignment == p]
            save(os.path.join(tmp, f"part_{p:05d}.npz"), rows=chunk)
            if len(chunk):
                mins.append(chunk.min(axis=0).tolist())
                maxs.append(chunk.max(axis=0).tolist())
            else:
                mins.append([float("inf")] * data.shape[1])
                maxs.append([float("-inf")] * data.shape[1])
            rows.append(int((assignment == p).sum()))
        manifest = {"num_partitions": k, "mins": mins, "maxs": maxs,
                    "rows": rows, "layout": layout.name}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        # Atomic swap (background reorganization completes, then the layout
        # pointer flips -- §III-B).
        if os.path.exists(self.root):
            shutil.rmtree(self.root)
        os.rename(tmp, self.root)
        return time.time() - t0

    # ------------------------------------------------------------------
    def reorganize(self, layout: L.Layout) -> float:
        """Full reorganization as the paper measures it (Table I): read every
        partition back from disk, update the BID column (re-route), shuffle
        rows into their new partitions (sort by BID), then compress and write
        the new partition files.  Returns seconds."""
        t0 = time.time()
        meta = self.metadata()
        chunks = []
        for p in range(meta.num_partitions):
            with np.load(os.path.join(self.root, f"part_{p:05d}.npz")) as z:
                chunks.append(z["rows"])
        data = np.concatenate([c for c in chunks if len(c)])
        bid = layout.route(data)                       # update BID column
        order = np.argsort(bid, kind="stable")         # shuffle by BID
        data = data[order]
        self.write(data, layout)
        return time.time() - t0

    # ------------------------------------------------------------------
    def metadata(self) -> L.PartitionMetadata:
        with open(os.path.join(self.root, "manifest.json")) as f:
            m = json.load(f)
        return L.PartitionMetadata(mins=np.array(m["mins"]),
                                   maxs=np.array(m["maxs"]),
                                   rows=np.array(m["rows"], dtype=np.float64))

    def scan(self, query: wl.Query) -> Tuple[np.ndarray, ScanStats]:
        """Execute a query: read only non-skippable partitions, filter rows."""
        t0 = time.time()
        meta = self.metadata()
        scanned = L.partitions_scanned(meta, query.lo, query.hi)
        chunks = []
        rows_read = 0
        for p in np.nonzero(scanned)[0]:
            with np.load(os.path.join(self.root, f"part_{p:05d}.npz")) as z:
                chunk = z["rows"]
            rows_read += len(chunk)
            mask = ((chunk >= query.lo[None, :])
                    & (chunk <= query.hi[None, :])).all(axis=1)
            chunks.append(chunk[mask])
        out = (np.concatenate(chunks) if chunks
               else np.zeros((0, meta.num_columns)))
        return out, ScanStats(int(scanned.sum()), meta.num_partitions,
                              rows_read, time.time() - t0)

    def full_scan_seconds(self) -> float:
        """Time a full table scan (the alpha denominator)."""
        meta = self.metadata()
        t0 = time.time()
        for p in range(meta.num_partitions):
            with np.load(os.path.join(self.root, f"part_{p:05d}.npz")) as z:
                _ = z["rows"].sum()
        return time.time() - t0
