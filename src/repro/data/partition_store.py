"""Columnar partition store: the physical layer under a data layout.

Materializes a layout (BID assignment) as one compressed file per partition
plus a metadata manifest -- the same structure the paper's Spark integration
uses (BID column + partition-level zone maps).  ``scan`` reads only the
partitions a query's predicates cannot skip; ``reorganize`` rewrites every
partition under a new layout (the alpha-cost operation measured in Table I).
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import time
from typing import Tuple

import numpy as np

from repro.core import layouts as L
from repro.core import workload as wl


@dataclasses.dataclass
class ScanStats:
    partitions_read: int
    partitions_total: int
    rows_read: int
    seconds: float


@dataclasses.dataclass
class ReorgStats:
    """Outcome of one :meth:`PartitionStore.reorganize` call.

    ``partitions_rewritten`` counts partitions whose row set changed under
    the new layout (re-compressed and rewritten); ``partitions_skipped``
    counts partitions whose row set is identical between the layouts —
    their files are carried over without re-routing, re-compressing or
    re-serializing a single row.
    """

    seconds: float
    partitions_rewritten: int
    partitions_skipped: int
    rows_rewritten: int

    def __float__(self) -> float:
        return self.seconds


def manifest_dict(num_partitions: int, mins, maxs, rows,
                  layout_name: str) -> dict:
    """The manifest as a plain dict — the single canonical construction,
    shared by :func:`write_manifest` and the durability WAL
    (:mod:`repro.data.wal`), so a replayed manifest is *bitwise* the one
    on disk."""
    return {"num_partitions": int(num_partitions),
            "mins": [list(m) for m in mins],
            "maxs": [list(m) for m in maxs],
            "rows": [int(r) for r in rows],
            "layout": layout_name}


def write_manifest(root: str, num_partitions: int, mins, maxs, rows,
                   layout_name: str) -> None:
    """Write a store directory's manifest — the single producer of the
    format :meth:`PartitionStore.metadata` parses, shared by full writes,
    skip-aware reorganization, and incremental migration completion."""
    manifest = manifest_dict(num_partitions, mins, maxs, rows, layout_name)
    with open(os.path.join(root, "manifest.json"), "w") as f:
        json.dump(manifest, f)


def chunk_bounds(chunk: np.ndarray, num_columns: int):
    """One partition's (mins, maxs) manifest rows; empty partitions carry
    the [+inf, -inf] identity bounds."""
    if len(chunk):
        return chunk.min(axis=0).tolist(), chunk.max(axis=0).tolist()
    return ([float("inf")] * num_columns, [float("-inf")] * num_columns)


class PartitionStore:
    """On-disk partitioned table with zone-map metadata."""

    def __init__(self, root: str):
        self.root = root
        # A crash mid-write/mid-reorganize leaves a fully- or partially-
        # written "<root>.tmp" staging directory behind (the swap in
        # _swap_in never happened, so the live directory is intact and
        # the orphan is pure garbage): reclaim it on open.
        orphan = root + ".tmp"
        if os.path.isdir(orphan):
            shutil.rmtree(orphan, ignore_errors=True)
        os.makedirs(root, exist_ok=True)

    def _fresh_tmp(self) -> str:
        tmp = self.root + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        return tmp

    def _swap_in(self, tmp: str) -> None:
        # Atomic swap (background reorganization completes, then the layout
        # pointer flips -- §III-B).
        if os.path.exists(self.root):
            shutil.rmtree(self.root)
        os.rename(tmp, self.root)

    # ------------------------------------------------------------------
    def write(self, data: np.ndarray, layout: L.Layout,
              compress: bool = True) -> float:
        """Full reorganization: route rows, rewrite all partition files.
        Returns seconds taken (the measured reorg cost)."""
        t0 = time.time()
        assignment = (layout.route(data) if layout.route is not None
                      else np.zeros(len(data), np.int64))
        tmp = self._fresh_tmp()
        k = layout.num_partitions
        mins, maxs, rows = [], [], []
        save = np.savez_compressed if compress else np.savez
        for p in range(k):
            chunk = data[assignment == p]
            save(os.path.join(tmp, f"part_{p:05d}.npz"), rows=chunk)
            lo, hi = chunk_bounds(chunk, data.shape[1])
            mins.append(lo)
            maxs.append(hi)
            rows.append(int((assignment == p).sum()))
        write_manifest(tmp, k, mins, maxs, rows, layout.name)
        self._swap_in(tmp)
        return time.time() - t0

    # ------------------------------------------------------------------
    def reorganize(self, layout: L.Layout) -> ReorgStats:
        """Reorganization as the paper measures it (Table I): read every
        partition back from disk, update the BID column (re-route), shuffle
        rows into their new partitions (sort by BID), then compress and
        write the new partition files — *except* partitions whose row set
        is unchanged between the layouts, whose existing files are carried
        over as-is instead of being pointlessly re-compressed (a layout
        switch between similar trees often leaves most partitions alone).
        Returns a :class:`ReorgStats` with the rewritten/skipped split.
        """
        t0 = time.time()
        meta = self.metadata()
        chunks = []
        for p in range(meta.num_partitions):
            with np.load(os.path.join(self.root, f"part_{p:05d}.npz")) as z:
                chunks.append(z["rows"])
        data = np.concatenate([c for c in chunks if len(c)]
                              or [np.zeros((0, meta.num_columns))])
        bid = (layout.route(data) if layout.route is not None
               else np.zeros(len(data), np.int64))     # update BID column
        order = np.argsort(bid, kind="stable")         # shuffle by BID
        k = layout.num_partitions

        # Old partition p is reusable for new partition p iff the row sets
        # coincide (order-insensitive: shuffling within a partition changes
        # neither its zone maps nor any scan result).
        def row_key(rows: np.ndarray) -> np.ndarray:
            return rows[np.lexsort(rows.T[::-1])] if len(rows) else rows

        tmp = self._fresh_tmp()
        mins, maxs, rows_out = [], [], []
        rewritten = skipped = rows_rewritten = 0
        save = np.savez_compressed
        sorted_bid = bid[order]
        bounds = np.searchsorted(sorted_bid, np.arange(k + 1))
        for p in range(k):
            chunk = data[order[bounds[p]:bounds[p + 1]]]
            # Reuse requires an existing file to carry over: a partition
            # index beyond the old layout's count is always (re)written.
            identical = (p < len(chunks)
                         and len(chunk) == len(chunks[p])
                         and np.array_equal(row_key(chunk),
                                            row_key(chunks[p])))
            if identical:
                shutil.copyfile(os.path.join(self.root, f"part_{p:05d}.npz"),
                                os.path.join(tmp, f"part_{p:05d}.npz"))
                skipped += 1
            else:
                save(os.path.join(tmp, f"part_{p:05d}.npz"), rows=chunk)
                rewritten += 1
                rows_rewritten += len(chunk)
            lo, hi = chunk_bounds(chunk, data.shape[1])
            mins.append(lo)
            maxs.append(hi)
            rows_out.append(int(len(chunk)))
        write_manifest(tmp, k, mins, maxs, rows_out, layout.name)
        self._swap_in(tmp)
        return ReorgStats(seconds=time.time() - t0,
                          partitions_rewritten=rewritten,
                          partitions_skipped=skipped,
                          rows_rewritten=rows_rewritten)

    # ------------------------------------------------------------------
    def metadata(self) -> L.PartitionMetadata:
        with open(os.path.join(self.root, "manifest.json")) as f:
            m = json.load(f)
        return L.PartitionMetadata(mins=np.array(m["mins"]),
                                   maxs=np.array(m["maxs"]),
                                   rows=np.array(m["rows"], dtype=np.float64))

    def scan(self, query: wl.Query) -> Tuple[np.ndarray, ScanStats]:
        """Execute a query: read only non-skippable partitions, filter rows."""
        t0 = time.time()
        meta = self.metadata()
        scanned = L.partitions_scanned(meta, query.lo, query.hi)
        chunks = []
        rows_read = 0
        for p in np.nonzero(scanned)[0]:
            with np.load(os.path.join(self.root, f"part_{p:05d}.npz")) as z:
                chunk = z["rows"]
            rows_read += len(chunk)
            mask = ((chunk >= query.lo[None, :])
                    & (chunk <= query.hi[None, :])).all(axis=1)
            chunks.append(chunk[mask])
        out = (np.concatenate(chunks) if chunks
               else np.zeros((0, meta.num_columns)))
        return out, ScanStats(int(scanned.sum()), meta.num_partitions,
                              rows_read, time.time() - t0)

    def full_scan_seconds(self) -> float:
        """Time a full table scan (the alpha denominator)."""
        meta = self.metadata()
        t0 = time.time()
        for p in range(meta.num_partitions):
            with np.load(os.path.join(self.root, f"part_{p:05d}.npz")) as z:
                _ = z["rows"].sum()
        return time.time() - t0
