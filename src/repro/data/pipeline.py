"""OREO-managed training-data pipeline: the paper's technique as a
first-class feature of the training framework.

A tokenized corpus lives in partition files whose zone maps cover metadata
columns (domain, quality score, length bucket, ingest time).  Data-selection
jobs -- mixture sampling, curriculum filtering, decontamination sweeps --
issue conjunctive range predicates over that metadata; every selection pays
for the partitions it cannot skip.  As the selection workload drifts (new
mixtures, new curricula), OREO decides online when re-partitioning the corpus
pays for itself, with the D-UMTS worst-case guarantee bounding the total
(scan + reorganize) cost.

``OreoDataPipeline`` wraps the OREO runner around the selection-query stream
and yields fixed-shape token batches for ``train_step``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import numpy as np

from repro.core import cost_model as cm
from repro.core import layout_manager as lm
from repro.core import layouts as L
from repro.core import mts, predictors
from repro.core import workload as wl
from repro.core.qdtree import build_default_layout


@dataclasses.dataclass
class PipelineStats:
    queries: int = 0
    scan_fraction_sum: float = 0.0
    reorgs: int = 0
    alpha: float = 80.0

    @property
    def mean_scan_fraction(self) -> float:
        return self.scan_fraction_sum / max(self.queries, 1)

    @property
    def total_cost(self) -> float:
        return self.scan_fraction_sum + self.reorgs * self.alpha


def synth_corpus(n_docs: int = 100_000, doc_len: int = 128, vocab: int = 50000,
                 seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """Synthetic corpus: metadata (N, 4) [domain, quality, length, time] +
    token matrix (N, doc_len)."""
    rng = np.random.default_rng(seed)
    domain = rng.integers(0, 32, n_docs).astype(float)
    quality = rng.beta(4, 2, n_docs)
    length = rng.integers(doc_len // 4, doc_len + 1, n_docs).astype(float)
    ingest = np.sort(rng.uniform(0, 1e6, n_docs))
    meta = np.stack([domain, quality, length, ingest], axis=1)
    tokens = rng.integers(0, vocab, (n_docs, doc_len), dtype=np.int32)
    return meta, tokens


class OreoDataPipeline:
    """Iterator of training batches whose selection queries are OREO-managed.

    Each ``next()``: (1) draws a selection query from the recipe stream,
    (2) feeds it to the LAYOUT MANAGER + D-UMTS REORGANIZER, (3) charges the
    scan fraction of the serving layout, (4) yields a (tokens, targets)
    batch drawn from the matching documents.
    """

    def __init__(self, meta: np.ndarray, tokens: np.ndarray,
                 recipe: Iterator[wl.Query],
                 batch_size: int = 8, seq_len: int = 128,
                 alpha: float = 80.0, gamma: float = 1.0,
                 technique: str = "qdtree",
                 target_partitions: int = 32,
                 manager_cfg: Optional[lm.LayoutManagerConfig] = None,
                 seed: int = 0):
        self.meta = meta
        self.tokens = tokens
        self.recipe = recipe
        self.batch_size = batch_size
        self.seq_len = seq_len
        self.rng = np.random.default_rng(seed)
        init = build_default_layout(0, meta, target_partitions)
        init.materialize(meta)
        mgr_cfg = manager_cfg or lm.LayoutManagerConfig(
            target_partitions=target_partitions)
        self.manager = lm.LayoutManager(meta, lm.make_generator(technique),
                                        init, mgr_cfg, seed=seed)
        self.dumts = mts.DynamicUMTS(
            alpha=alpha, initial_states=[0], seed=seed,
            transition_fn=predictors.gamma_biased_transition(gamma))
        self.cost_model = cm.CostModel(alpha=alpha)
        self.serving = init
        self.stats = PipelineStats(alpha=alpha)

    # ------------------------------------------------------------------
    def _observe(self, q: wl.Query) -> None:
        added, removed = self.manager.on_query(q, self.dumts.current_state)
        for sid in added:
            self.dumts.add_state(sid)
        for sid in removed:
            self.dumts.remove_state(sid)
        costs = {}
        for sid in set(self.dumts.states) | set(self.dumts.pending_additions):
            lay = self.manager.store.get(sid)
            costs[sid] = (self.cost_model.query_cost(lay, q)
                          if lay is not None else 1.0)
        prev = self.dumts.num_moves
        state = self.dumts.observe(costs)
        if self.dumts.num_moves > prev:
            # Background reorganization: materialize the new layout.
            self.stats.reorgs += 1
            lay = self.manager.store.get(state)
            if lay is not None:
                lay.materialize(self.meta)
                self.serving = lay

    # ------------------------------------------------------------------
    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        q = next(self.recipe)
        self._observe(q)
        frac = float(L.eval_cost(self.serving.serving_meta(), q.lo, q.hi))
        self.stats.queries += 1
        self.stats.scan_fraction_sum += frac
        # Select matching documents (the actual read).
        mask = ((self.meta >= q.lo[None, :])
                & (self.meta <= q.hi[None, :])).all(axis=1)
        idx = np.nonzero(mask)[0]
        if len(idx) == 0:
            idx = np.arange(len(self.meta))
        pick = self.rng.choice(idx, size=self.batch_size, replace=True)
        toks = self.tokens[pick][:, :self.seq_len].astype(np.int32)
        targets = np.roll(toks, -1, axis=1)
        targets[:, -1] = -1
        return {"tokens": toks, "targets": targets}


def mixture_recipe(meta: np.ndarray, total_steps: int, seed: int = 0,
                   segment_length: Tuple[int, int] = (200, 600)
                   ) -> Iterator[wl.Query]:
    """Drifting data-selection recipe: phases of domain-focused, quality-
    thresholded, or recency-windowed selection (the drift OREO adapts to)."""
    rng = np.random.default_rng(seed)
    col_lo, col_hi = meta.min(0), meta.max(0)
    c = meta.shape[1]
    step = 0
    while step < total_steps:
        seg = int(rng.integers(*segment_length))
        kind = rng.integers(0, 3)
        lo = np.full(c, -np.inf)
        hi = np.full(c, np.inf)
        if kind == 0:        # domain band
            d0 = rng.integers(0, 28)
            lo[0], hi[0] = d0, d0 + rng.integers(1, 4)
        elif kind == 1:      # quality threshold
            lo[1] = rng.uniform(0.6, 0.9)
        else:                # recency window
            width = (col_hi[3] - col_lo[3]) * rng.uniform(0.05, 0.2)
            start = rng.uniform(col_lo[3], col_hi[3] - width)
            lo[3], hi[3] = start, start + width
        for _ in range(min(seg, total_steps - step)):
            jl, jh = lo.copy(), hi.copy()
            if np.isfinite(hi[3]) and kind == 2:   # jitter time windows
                shift = rng.uniform(-0.01, 0.01) * (col_hi[3] - col_lo[3])
                jl[3] += shift
                jh[3] += shift
            yield wl.Query(lo=jl, hi=jh, template_id=int(kind))
            step += 1
