"""Synthetic dataset generators mirroring the paper's three workloads.

* TPC-H-like: denormalized lineitem-style fact table -- mixed uniform /
  exponential / correlated-date / low-cardinality-categorical columns.
* TPC-DS-like: store_sales-style fact table with dimension-coded columns and
  skewed (Zipf) categorical distributions.
* Telemetry-like: ingestion-log table dominated by an arrival-time column
  (queries are time ranges + collector filters), matching the SuperCollider
  description in §VI-A2.

All generators return (data (N, C) float64, column_names).
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np


def make_tpch_like(n_rows: int = 200_000, seed: int = 0
                   ) -> Tuple[np.ndarray, List[str]]:
    rng = np.random.default_rng(seed)
    n = n_rows
    ship_date = rng.uniform(0, 2500, n)                      # days
    commit_date = ship_date + rng.normal(30, 15, n)          # correlated
    receipt_date = ship_date + np.abs(rng.normal(14, 7, n))
    quantity = rng.integers(1, 51, n).astype(float)
    extended_price = quantity * rng.uniform(900, 105000 / 50, n)
    discount = rng.choice(np.arange(0, 0.11, 0.01), n)
    tax = rng.choice(np.arange(0, 0.09, 0.01), n)
    order_key = np.sort(rng.uniform(0, 6e6, n))              # clustered
    part_key = rng.uniform(0, 2e5, n)
    supp_key = rng.uniform(0, 1e4, n)
    line_status = rng.integers(0, 2, n).astype(float)
    return_flag = rng.integers(0, 3, n).astype(float)
    cols = np.stack([ship_date, commit_date, receipt_date, quantity,
                     extended_price, discount, tax, order_key, part_key,
                     supp_key, line_status, return_flag], axis=1)
    names = ["ship_date", "commit_date", "receipt_date", "quantity",
             "extended_price", "discount", "tax", "order_key", "part_key",
             "supp_key", "line_status", "return_flag"]
    return cols, names


def make_tpcds_like(n_rows: int = 200_000, seed: int = 1
                    ) -> Tuple[np.ndarray, List[str]]:
    rng = np.random.default_rng(seed)
    n = n_rows
    sold_date = np.sort(rng.uniform(2450000, 2453000, n))    # julian days
    sold_time = rng.uniform(0, 86400, n)
    item = rng.zipf(1.5, n).clip(max=18000).astype(float)
    customer = rng.uniform(0, 1e5, n)
    store = rng.zipf(1.3, n).clip(max=400).astype(float)
    promo = rng.zipf(2.0, n).clip(max=300).astype(float)
    quantity = rng.integers(1, 100, n).astype(float)
    wholesale = rng.uniform(1, 100, n)
    list_price = wholesale * rng.uniform(1.0, 2.0, n)
    sales_price = list_price * rng.uniform(0.2, 1.0, n)
    ext_discount = (list_price - sales_price) * quantity
    net_paid = sales_price * quantity
    net_profit = net_paid - wholesale * quantity
    cols = np.stack([sold_date, sold_time, item, customer, store, promo,
                     quantity, wholesale, list_price, sales_price,
                     ext_discount, net_paid, net_profit], axis=1)
    names = ["sold_date", "sold_time", "item", "customer", "store", "promo",
             "quantity", "wholesale", "list_price", "sales_price",
             "ext_discount", "net_paid", "net_profit"]
    return cols, names


def make_telemetry_like(n_rows: int = 200_000, seed: int = 2
                        ) -> Tuple[np.ndarray, List[str]]:
    rng = np.random.default_rng(seed)
    n = n_rows
    arrival = np.sort(rng.uniform(0, 180 * 86400, n))        # 6 months
    collector = rng.zipf(1.4, n).clip(max=120).astype(float)
    job_id = rng.uniform(0, 5e4, n)
    duration = np.abs(rng.normal(300, 200, n))
    rows_in = np.abs(rng.normal(1e6, 5e5, n))
    bytes_in = rows_in * rng.uniform(50, 200, n)
    status = rng.choice([0, 1, 2], n, p=[0.9, 0.07, 0.03]).astype(float)
    team = rng.zipf(1.6, n).clip(max=100).astype(float)
    retries = rng.poisson(0.2, n).astype(float)
    cols = np.stack([arrival, collector, job_id, duration, rows_in,
                     bytes_in, status, team, retries], axis=1)
    names = ["arrival_time", "collector", "job_id", "duration", "rows_in",
             "bytes_in", "status", "team", "retries"]
    return cols, names


DATASETS = {
    "tpch": make_tpch_like,
    "tpcds": make_tpcds_like,
    "telemetry": make_telemetry_like,
}


def telemetry_templates(num_columns: int, seed: int = 0):
    """Telemetry-flavored templates matching the paper's description of the
    SuperCollider trace: time-range queries (hours..months), collector-name
    filters, plus job-debugging families (team dashboards, failure triage,
    long-job investigations, volume outliers) that conflict with pure
    time-ordering."""
    from repro.core import workload as wl
    rng = np.random.default_rng(seed)
    templates = []
    tid = 0
    for hours in (6, 48, 24 * 30):     # time-range families
        sel = hours * 3600 / (180 * 86400)
        templates.append(wl.QueryTemplate(tid, (0,), (min(sel, 1.0),)))
        tid += 1
    for _ in range(2):                 # collector + time families
        templates.append(wl.QueryTemplate(
            tid, (1, 0), (float(rng.uniform(0.01, 0.05)),
                          float(rng.uniform(0.05, 0.2)))))
        tid += 1
    # cols: 2=job_id 3=duration 4=rows_in 5=bytes_in 6=status 7=team
    templates.append(wl.QueryTemplate(tid, (7,), (0.03,))); tid += 1
    templates.append(wl.QueryTemplate(tid, (6, 3), (0.05, 0.1))); tid += 1
    templates.append(wl.QueryTemplate(tid, (3,), (0.05,))); tid += 1
    templates.append(wl.QueryTemplate(tid, (4, 5), (0.08, 0.15))); tid += 1
    templates.append(wl.QueryTemplate(tid, (2,), (0.04,))); tid += 1
    return templates
