"""Crash-safe manifest write-ahead log for on-disk partition stores.

A :class:`ManifestWAL` makes the *metadata* side of a store durable: every
mutation of the logical manifest state — the initial table write, a delta
batch landing from streaming ingest, an incremental-migration micro-batch,
a layout swap — is appended to ``log.jsonl`` **before** the mutation is
considered applied, and a periodic ``snapshot.json`` bounds replay work.

The manifest state is a plain JSON dict and recovery is a *pure left fold*
over the logged records (:func:`apply_record`), which gives the two
properties the crash tests pin down:

* **idempotent / crash-point-invariant replay** — for any prefix of the
  log, replaying the prefix and then continuing with the remaining
  records yields a state bitwise equal (via :func:`canonical_manifest`)
  to the uninterrupted fold, so it never matters where the crash landed;
* **torn-tail tolerance** — a crash mid-append leaves at most one
  incomplete final line, which replay discards (every complete record was
  durably applied before the mutation it describes took effect).

Snapshots are written atomically (tmp file + rename) and record how many
log records they already include (``applied``), so a crash between the
snapshot rename and any subsequent append cannot double-apply records.
"""
from __future__ import annotations

import json
import os
from typing import Callable, Dict, List, Optional, Tuple

#: The empty manifest state every fold starts from.
INITIAL_STATE: Dict = {"serving": None, "manifest": None, "deltas": [],
                       "migration": None}


def _fresh_state() -> Dict:
    return json.loads(json.dumps(INITIAL_STATE))


def apply_record(state: Dict, record: Dict) -> Dict:
    """Pure reducer: one logged record folded into the manifest state.

    Ops:

    * ``init`` / ``swap`` — a store became the serving table (initial
      write, atomic reorg, or incremental-migration completion): install
      its manifest, clear absorbed deltas and any in-flight migration.
    * ``append_delta`` — a streaming-ingest batch landed as an
      unclustered delta partition (exact zone maps in the record).
    * ``migration_begin`` / ``migration_apply`` — an incremental
      migration opened a partial target store / completed a micro-batch
      of target partitions.
    * ``snapshot_marker`` — no-op (kept for log readability).
    """
    state = dict(state)
    op = record.get("op")
    if op in ("init", "swap"):
        state["serving"] = record.get("store")
        state["manifest"] = record["manifest"]
        state["deltas"] = []
        state["migration"] = None
    elif op == "append_delta":
        state["deltas"] = list(state["deltas"]) + [{
            "batch_id": record["batch_id"],
            "file": record.get("file"),
            "mins": record["mins"],
            "maxs": record["maxs"],
            "rows": record["rows"],
        }]
    elif op == "migration_begin":
        state["migration"] = {"store": record.get("store"),
                              "target_state": record.get("target_state"),
                              "num_targets": record.get("num_targets"),
                              "done": []}
    elif op == "migration_apply":
        mig = dict(state["migration"] or {"done": []})
        mig["done"] = sorted(set(mig.get("done", []))
                             | set(record.get("done", [])))
        state["migration"] = mig
    elif op == "snapshot_marker":
        pass
    else:
        raise ValueError(f"unknown WAL op: {op!r}")
    return state


def canonical_manifest(state: Dict) -> bytes:
    """Canonical byte serialization of a manifest state.

    Two states are *the same manifest* iff their canonical bytes are
    equal — the bitwise-identity the crash-injection tests assert.
    """
    return json.dumps(state, sort_keys=True,
                      separators=(",", ":")).encode("utf-8")


class ManifestWAL:
    """Append-only JSONL log + atomic snapshots under one directory."""

    LOG = "log.jsonl"
    SNAPSHOT = "snapshot.json"

    def __init__(self, root: str, snapshot_every: int = 64,
                 sync: bool = False):
        self.root = root
        self.snapshot_every = max(int(snapshot_every), 1)
        self.sync = sync
        os.makedirs(root, exist_ok=True)
        # Reclaim a torn snapshot tmp left by a crash mid-snapshot.
        tmp = os.path.join(root, self.SNAPSHOT + ".tmp")
        if os.path.exists(tmp):
            os.remove(tmp)
        self._log_path = os.path.join(root, self.LOG)
        self._records_since_snapshot = 0

    # -- writing -------------------------------------------------------
    def append(self, record: Dict) -> None:
        """Durably log one record (the mutation may only proceed after)."""
        line = json.dumps(record, sort_keys=True) + "\n"
        with open(self._log_path, "a") as f:
            f.write(line)
            f.flush()
            if self.sync:
                os.fsync(f.fileno())
        self._records_since_snapshot += 1
        if self._records_since_snapshot >= self.snapshot_every:
            self.snapshot(self.replay())

    def snapshot(self, state: Dict) -> None:
        """Atomically persist ``state`` as the new replay starting point."""
        applied = len(self.records())
        tmp = os.path.join(self.root, self.SNAPSHOT + ".tmp")
        with open(tmp, "w") as f:
            json.dump({"applied": applied, "state": state}, f,
                      sort_keys=True)
            f.flush()
            if self.sync:
                os.fsync(f.fileno())
        os.replace(tmp, os.path.join(self.root, self.SNAPSHOT))
        self._records_since_snapshot = 0

    # -- reading -------------------------------------------------------
    def records(self) -> List[Dict]:
        """Every complete logged record, oldest first (torn tail dropped)."""
        if not os.path.exists(self._log_path):
            return []
        out: List[Dict] = []
        with open(self._log_path) as f:
            for line in f:
                try:
                    out.append(json.loads(line))
                except json.JSONDecodeError:
                    break           # torn tail from a crash mid-append
        return out

    def _snapshot_point(self) -> Tuple[int, Dict]:
        path = os.path.join(self.root, self.SNAPSHOT)
        if not os.path.exists(path):
            return 0, _fresh_state()
        with open(path) as f:
            snap = json.load(f)
        return int(snap["applied"]), snap["state"]

    def replay(self, apply_fn: Optional[Callable[[Dict, Dict], Dict]] = None,
               ) -> Dict:
        """Fold snapshot + remaining log records into the manifest state."""
        apply_fn = apply_fn or apply_record
        applied, state = self._snapshot_point()
        for record in self.records()[applied:]:
            state = apply_fn(state, record)
        return state


def replay_records(records: List[Dict],
                   state: Optional[Dict] = None) -> Dict:
    """Pure fold over an in-memory record list (the property-test oracle)."""
    out = _fresh_state() if state is None else state
    for record in records:
        out = apply_record(out, record)
    return out


__all__ = ["INITIAL_STATE", "ManifestWAL", "apply_record",
           "canonical_manifest", "replay_records"]
