"""Data substrate: synthetic datasets, partition store, OREO-managed pipeline."""
from repro.data import datasets, partition_store, pipeline
from repro.data.datasets import (DATASETS, make_telemetry_like,
                                 make_tpcds_like, make_tpch_like)
from repro.data.partition_store import PartitionStore
from repro.data.pipeline import OreoDataPipeline, mixture_recipe, synth_corpus

__all__ = ["DATASETS", "OreoDataPipeline", "PartitionStore",
           "make_telemetry_like", "make_tpcds_like", "make_tpch_like",
           "mixture_recipe", "synth_corpus", "datasets", "partition_store",
           "pipeline"]
