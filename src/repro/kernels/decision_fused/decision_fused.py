"""Pallas TPU megakernel: the whole per-tick decision plane in one pass.

Every tick the engine needs three products of the same packed fleet plane
``(T, S, P, C)``: the candidate-state scan matrix for cost scoring, the
serve-shadow score (the shadow state's lane of that same matrix), and —
for migration-planning tenants — per-partition scan frequencies over the
recent-query window.  Run as three separate kernels
(:mod:`repro.kernels.pruning`, :mod:`repro.kernels.fleet_scan`,
:mod:`repro.kernels.move_score`) the bounds tensors stream from HBM three
times per tick; this kernel reads them once and emits all three outputs:

  grid = (T/BT, P/BP), partition blocks innermost.  Each program holds the
  (B, BT, C) frame queries, the (W, 1, C) recent-query window, and one
  (BT, S, BP, C) bounds tile in VMEM (the pipeline double-buffers the
  streamed operands automatically), accumulates overlap ANDs over column
  chunks, and writes

  * ``scan`` (B, BT, S, BP) — its 0/1 block of the frame scan matrix;
  * ``cost`` (B, BT, S) — scanned-row fraction, accumulated across the
    inner partition-block axis (``@pl.when(j == 0)`` zero-init, partial
    ``sum_p scan * rows * inv_totals`` added per block — the output block
    index ignores j so revisits are consecutive);
  * ``freq`` (BT, S, BP) — mean window overlap, the move planner's
    ordering signal.

The candidate axis S rides whole inside each block (S_cap is small), as do
the frame axis B and window axis W.  Like the three kernels it fuses, this
is VPU-bound and memory-bound (~C flops/byte over metadata); the win is
one HBM pass over ``(T, S, P, C)`` bounds per tick instead of three, and
one launch for all B frames instead of B ``fleet_scan`` launches.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels._backend import resolve_interpret

DEFAULT_BT = 4
DEFAULT_BP = 128


def _overlap(qlo, qhi, pmin, pmax, col_chunk):
    """(K, KT, C) queries x (BT, S, BP, C) bounds -> (K, BT, S, BP) 0/1.

    KT is either BT (per-tenant frame queries) or 1 (a shared window row
    broadcast to every tenant in the block).
    """
    k, kt, c = qlo.shape
    bt, s, bp, _ = pmin.shape
    acc = jnp.ones((k, bt, s, bp), jnp.float32)
    n_chunks = pl.cdiv(c, col_chunk)
    for i in range(n_chunks):
        lo = i * col_chunk
        width = min(col_chunk, c - lo)
        ql = jax.lax.dynamic_slice(qlo, (0, 0, lo), (k, kt, width))
        qh = jax.lax.dynamic_slice(qhi, (0, 0, lo), (k, kt, width))
        pn = jax.lax.dynamic_slice(pmin, (0, 0, 0, lo), (bt, s, bp, width))
        px = jax.lax.dynamic_slice(pmax, (0, 0, 0, lo), (bt, s, bp, width))
        ov = ((pn[None] <= qh[:, :, None, None, :])
              & (px[None] >= ql[:, :, None, None, :]))
        acc = acc * ov.all(axis=-1).astype(jnp.float32)
    return acc


def _make_kernel(*, col_chunk, emit_scan, emit_cost, emit_freq):
    def kernel(*refs):
        it = iter(refs)
        qlo_ref, qhi_ref, pmin_ref, pmax_ref = (next(it) for _ in range(4))
        rows_ref = inv_ref = wlo_ref = whi_ref = None
        if emit_cost:
            rows_ref, inv_ref = next(it), next(it)
        if emit_freq:
            wlo_ref, whi_ref = next(it), next(it)
        outs = list(it)

        pmin = pmin_ref[...]                  # (BT, S, BP, C)
        pmax = pmax_ref[...]
        if emit_scan or emit_cost:
            scan = _overlap(qlo_ref[...], qhi_ref[...], pmin, pmax,
                            col_chunk)        # (B, BT, S, BP)
        if emit_scan:
            outs.pop(0)[...] = scan
        if emit_cost:
            cost_ref = outs.pop(0)            # (B, BT, S), revisited over j
            part = ((scan * rows_ref[...][None]).sum(axis=-1)
                    * inv_ref[...][None])

            @pl.when(pl.program_id(1) == 0)
            def _init():
                cost_ref[...] = jnp.zeros_like(cost_ref)

            cost_ref[...] += part
        if emit_freq:
            wov = _overlap(wlo_ref[...], whi_ref[...], pmin, pmax,
                           col_chunk)         # (W, BT, S, BP)
            outs.pop(0)[...] = jnp.mean(wov, axis=0)
    return kernel


def fused_decision_pallas(q_lo: jax.Array, q_hi: jax.Array,
                          p_min: jax.Array, p_max: jax.Array,
                          rows: Optional[jax.Array] = None,
                          inv_totals: Optional[jax.Array] = None,
                          w_lo: Optional[jax.Array] = None,
                          w_hi: Optional[jax.Array] = None,
                          *, emit_scan: bool = True, bt: int = DEFAULT_BT,
                          bp: int = DEFAULT_BP, col_chunk: int = 8,
                          interpret: Optional[bool] = None,
                          ) -> Tuple[Optional[jax.Array],
                                     Optional[jax.Array],
                                     Optional[jax.Array]]:
    """(B, T, C) frame queries x (T, S, P, C) plane -> (scan, cost, freq).

    Output semantics match :func:`repro.kernels.decision_fused.ref.
    fused_decision`; each element of the returned triple is ``None`` when
    its inputs were not supplied (``cost`` needs ``rows`` (T, S, P) and
    ``inv_totals`` (T, S); ``freq`` needs the (W, C) window bounds) or,
    for ``scan``, when ``emit_scan=False``.  ``interpret=None``
    auto-selects via :func:`repro.kernels._backend.resolve_interpret`.
    """
    emit_cost = rows is not None
    emit_freq = w_lo is not None
    if not (emit_scan or emit_cost or emit_freq):
        raise ValueError("fused_decision_pallas: nothing to emit")
    return _fused_call(q_lo, q_hi, p_min, p_max, rows, inv_totals,
                       w_lo, w_hi, emit_scan=emit_scan, emit_cost=emit_cost,
                       emit_freq=emit_freq, bt=bt, bp=bp,
                       col_chunk=col_chunk,
                       interpret=resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("emit_scan", "emit_cost",
                                             "emit_freq", "bt", "bp",
                                             "col_chunk", "interpret"))
def _fused_call(q_lo, q_hi, p_min, p_max, rows, inv_totals, w_lo, w_hi, *,
                emit_scan, emit_cost, emit_freq, bt, bp, col_chunk,
                interpret):
    B, T, C = q_lo.shape
    _, S, P, _ = p_min.shape
    bt = min(bt, T)
    bp = min(bp, P)
    pad_t = (-T) % bt
    pad_p = (-P) % bp
    if pad_t:
        # Padded tenants get empty queries ([1, 0] per column) and empty
        # bounds, zero rows and zero inverse totals: all outputs 0, sliced
        # away below.
        q_lo = jnp.pad(q_lo, ((0, 0), (0, pad_t), (0, 0)),
                       constant_values=1.0)
        q_hi = jnp.pad(q_hi, ((0, 0), (0, pad_t), (0, 0)),
                       constant_values=0.0)
        p_min = jnp.pad(p_min, ((0, pad_t), (0, 0), (0, 0), (0, 0)),
                        constant_values=1.0)
        p_max = jnp.pad(p_max, ((0, pad_t), (0, 0), (0, 0), (0, 0)),
                        constant_values=0.0)
        if emit_cost:
            rows = jnp.pad(rows, ((0, pad_t), (0, 0), (0, 0)))
            inv_totals = jnp.pad(inv_totals, ((0, pad_t), (0, 0)))
    if pad_p:
        # Padded partition slots get empty bounds: never scanned.
        p_min = jnp.pad(p_min, ((0, 0), (0, 0), (0, pad_p), (0, 0)),
                        constant_values=1.0)
        p_max = jnp.pad(p_max, ((0, 0), (0, 0), (0, pad_p), (0, 0)),
                        constant_values=0.0)
        if emit_cost:
            rows = jnp.pad(rows, ((0, 0), (0, 0), (0, pad_p)))
    Tp, Pp = T + pad_t, P + pad_p
    grid = (Tp // bt, Pp // bp)

    arrays = [q_lo, q_hi, p_min, p_max]
    in_specs = [
        pl.BlockSpec((B, bt, C), lambda i, j: (0, i, 0)),
        pl.BlockSpec((B, bt, C), lambda i, j: (0, i, 0)),
        pl.BlockSpec((bt, S, bp, C), lambda i, j: (i, 0, j, 0)),
        pl.BlockSpec((bt, S, bp, C), lambda i, j: (i, 0, j, 0)),
    ]
    if emit_cost:
        arrays += [rows, inv_totals]
        in_specs += [
            pl.BlockSpec((bt, S, bp), lambda i, j: (i, 0, j)),
            pl.BlockSpec((bt, S), lambda i, j: (i, 0)),
        ]
    if emit_freq:
        W = w_lo.shape[0]
        arrays += [w_lo[:, None, :], w_hi[:, None, :]]
        in_specs += [
            pl.BlockSpec((W, 1, C), lambda i, j: (0, 0, 0)),
            pl.BlockSpec((W, 1, C), lambda i, j: (0, 0, 0)),
        ]
    out_specs, out_shapes = [], []
    if emit_scan:
        out_specs.append(pl.BlockSpec((B, bt, S, bp),
                                      lambda i, j: (0, i, 0, j)))
        out_shapes.append(jax.ShapeDtypeStruct((B, Tp, S, Pp), jnp.float32))
    if emit_cost:
        out_specs.append(pl.BlockSpec((B, bt, S), lambda i, j: (0, i, 0)))
        out_shapes.append(jax.ShapeDtypeStruct((B, Tp, S), jnp.float32))
    if emit_freq:
        out_specs.append(pl.BlockSpec((bt, S, bp), lambda i, j: (i, 0, j)))
        out_shapes.append(jax.ShapeDtypeStruct((Tp, S, Pp), jnp.float32))

    outs = pl.pallas_call(
        _make_kernel(col_chunk=col_chunk, emit_scan=emit_scan,
                     emit_cost=emit_cost, emit_freq=emit_freq),
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shapes,
        interpret=interpret,
    )(*arrays)
    outs = list(outs)
    scan = outs.pop(0)[:, :T, :, :P] if emit_scan else None
    cost = outs.pop(0)[:, :T, :] if emit_cost else None
    freq = outs.pop(0)[:T, :, :P] if emit_freq else None
    return scan, cost, freq
