"""Jit'd public wrapper for the fused decision megakernel.

Dispatches to the Pallas megakernel on accelerator backends (compiled) /
interpret mode on CPU, and to the jnp oracle when the kernel is bypassed
(`use_kernel=False`) — the oracle is one fused XLA computation, so it is
also the compiled lane the kernel benchmark times on CPU-only hosts.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax

from repro.kernels.decision_fused import decision_fused, ref


def fused_decision(q_lo, q_hi, p_min, p_max, rows=None, inv_totals=None,
                   w_lo=None, w_hi=None, use_kernel: bool = True,
                   **block_kw) -> Tuple[Optional[jax.Array],
                                        Optional[jax.Array],
                                        Optional[jax.Array]]:
    """(B, T, C) x (T, S, P, C) -> (scan, cost, freq), one operand pass.

    ``cost`` requires ``rows`` (T, S, P) and ``inv_totals`` (T, S);
    ``freq`` requires the (W, C) recent-query window bounds.  Elements of
    the triple not requested come back ``None``.
    """
    if not use_kernel:
        return _ref_call(q_lo, q_hi, p_min, p_max, rows, inv_totals,
                         w_lo, w_hi)
    return decision_fused.fused_decision_pallas(
        q_lo, q_hi, p_min, p_max, rows, inv_totals, w_lo, w_hi, **block_kw)


@functools.partial(jax.jit, static_argnames=())
def _ref_call(q_lo, q_hi, p_min, p_max, rows, inv_totals, w_lo, w_hi):
    return ref.fused_decision(q_lo, q_hi, p_min, p_max, rows, inv_totals,
                              w_lo, w_hi)
