"""jnp oracle for the fused decision megakernel.

Computes the same three products as
:func:`repro.kernels.decision_fused.decision_fused.fused_decision_pallas`
by materializing the broadcast tensors directly — the ground truth the
kernel is tested against, and the compiled-XLA lane the benchmark times
when Mosaic is unavailable.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def fused_decision(q_lo: jax.Array, q_hi: jax.Array, p_min: jax.Array,
                   p_max: jax.Array, rows: Optional[jax.Array] = None,
                   inv_totals: Optional[jax.Array] = None,
                   w_lo: Optional[jax.Array] = None,
                   w_hi: Optional[jax.Array] = None,
                   ) -> Tuple[jax.Array, Optional[jax.Array],
                              Optional[jax.Array]]:
    """One pass over the packed fleet plane, three decision products.

    * ``scan``: (B, T, S, P) float32 0/1 — frame b's query for tenant t
      overlaps partition p of candidate state s (the serve-shadow score is
      the shadow state's lane of this tensor);
    * ``cost``: (B, T, S) float32 — scanned-row fraction per candidate
      state, ``sum_p scan * rows * inv_totals`` (``None`` unless ``rows``
      and ``inv_totals`` are given);
    * ``freq``: (T, S, P) float32 — fraction of the (W, C) recent-query
      window scanning each partition, the micro-move planner's ordering
      signal (``None`` unless ``w_lo``/``w_hi`` are given).
    """
    scan = ((p_min[None] <= q_hi[:, :, None, None, :])
            & (p_max[None] >= q_lo[:, :, None, None, :]))
    scan = scan.all(axis=-1).astype(jnp.float32)          # (B, T, S, P)
    cost = None
    if rows is not None:
        cost = ((scan * rows[None]).sum(axis=-1)
                * inv_totals[None])                       # (B, T, S)
    freq = None
    if w_lo is not None:
        wov = ((p_min[None] <= w_hi[:, None, None, None, :])
               & (p_max[None] >= w_lo[:, None, None, None, :]))
        freq = wov.all(axis=-1).astype(jnp.float32).mean(axis=0)  # (T, S, P)
    return scan, cost, freq
