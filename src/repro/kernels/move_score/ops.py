"""Jit'd public wrapper for the move-score kernel.

Dispatches to the Pallas kernel on accelerator backends (compiled) /
interpret mode on CPU, and to the jnp oracle when the kernel is bypassed.
The benefit *combination* (block-row weighting of the frequencies) lives
in one place only — :func:`repro.engine.reorg.planner.plan_migration` —
so the ordering formula cannot drift between implementations.
"""
from __future__ import annotations

import jax

from repro.kernels.move_score import move_score, ref


def move_scan_frequencies(q_lo, q_hi, p_min, p_max, use_kernel: bool = True,
                          **block_kw) -> jax.Array:
    """(Q, C) x (S, P, C) -> (S, P) per-partition scan frequencies."""
    if not use_kernel:
        return ref.move_scores(q_lo, q_hi, p_min, p_max)
    return move_score.move_scores_pallas(q_lo, q_hi, p_min, p_max,
                                         **block_kw)
