"""Pallas TPU kernel: score all (state, partition) move candidates at once.

The micro-move planner (:mod:`repro.engine.reorg.planner`) orders a
migration's moves by estimated skipping-benefit-per-row under the recent
query distribution.  The expensive part is the per-partition *scan
frequency*: for every partition of every involved layout state, the
fraction of the Q recent queries whose predicates cannot skip it.  That is
a (Q, S, P, C) interval-overlap AND-reduction followed by a mean over
queries — this kernel fuses it into one launch over the packed
``(S, P, C)`` bounds plane:

  grid = (S, P/BP); each program holds the full (Q, C) query sample (the
  recent window is small — it rides along every program) and one
  (1, BP, C) bounds tile in VMEM, accumulates the (Q, BP) overlap AND
  over column chunks, then reduces the query axis to the (1, BP) mean, so
  the (Q, S, P, C) broadcast tensor never materializes.

Like the sibling pruning/fleet_scan kernels this is VPU-bound and
memory-bound (~C flops/byte over metadata); block sizes keep the working
set (2*Q*C + 2*BP*C + Q*BP floats) well under VMEM.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels._backend import resolve_interpret

DEFAULT_BP = 128


def _kernel(qlo_ref, qhi_ref, pmin_ref, pmax_ref, out_ref, *, col_chunk):
    qlo = qlo_ref[...]            # (Q, C)
    qhi = qhi_ref[...]
    pmin = pmin_ref[...]          # (1, BP, C)
    pmax = pmax_ref[...]
    q, c = qlo.shape
    bp = pmin.shape[1]
    acc = jnp.ones((q, bp), jnp.float32)
    n_chunks = pl.cdiv(c, col_chunk)
    for i in range(n_chunks):
        lo = i * col_chunk
        width = min(col_chunk, c - lo)
        ql = jax.lax.dynamic_slice(qlo, (0, lo), (q, width))
        qh = jax.lax.dynamic_slice(qhi, (0, lo), (q, width))
        pn = jax.lax.dynamic_slice(pmin, (0, 0, lo), (1, bp, width))
        px = jax.lax.dynamic_slice(pmax, (0, 0, lo), (1, bp, width))
        ov = ((pn[0][None, :, :] <= qh[:, None, :])
              & (px[0][None, :, :] >= ql[:, None, :]))
        acc = acc * ov.all(axis=-1).astype(jnp.float32)
    out_ref[...] = jnp.mean(acc, axis=0, keepdims=True)   # (1, BP)


def move_scores_pallas(q_lo: jax.Array, q_hi: jax.Array, p_min: jax.Array,
                       p_max: jax.Array, bp: int = DEFAULT_BP,
                       col_chunk: int = 8,
                       interpret: Optional[bool] = None) -> jax.Array:
    """(Q, C) queries x (S, P, C) bounds -> (S, P) float32 scan frequency.

    ``out[s, p]`` is the fraction of queries scanning partition p of state
    s.  ``interpret=None`` auto-selects: the compiled kernel when JAX has
    an accelerator backend (TPU/GPU), the Pallas interpreter on CPU-only
    hosts.
    """
    return _move_scores_call(q_lo, q_hi, p_min, p_max, bp=bp,
                             col_chunk=col_chunk,
                             interpret=resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("bp", "col_chunk", "interpret"))
def _move_scores_call(q_lo: jax.Array, q_hi: jax.Array, p_min: jax.Array,
                      p_max: jax.Array, bp: int, col_chunk: int,
                      interpret: bool) -> jax.Array:
    S, P, C = p_min.shape
    bp = min(bp, P)
    pad_p = (-P) % bp
    if pad_p:
        # Padded partition slots get empty bounds ([1, 0] per column):
        # never scanned for any query, and sliced away below either way.
        p_min = jnp.pad(p_min, ((0, 0), (0, pad_p), (0, 0)),
                        constant_values=1.0)
        p_max = jnp.pad(p_max, ((0, 0), (0, pad_p), (0, 0)),
                        constant_values=0.0)
    Pp = P + pad_p
    grid = (S, Pp // bp)
    Q = q_lo.shape[0]
    out = pl.pallas_call(
        functools.partial(_kernel, col_chunk=col_chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((Q, C), lambda s, j: (0, 0)),
            pl.BlockSpec((Q, C), lambda s, j: (0, 0)),
            pl.BlockSpec((1, bp, C), lambda s, j: (s, j, 0)),
            pl.BlockSpec((1, bp, C), lambda s, j: (s, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bp), lambda s, j: (s, j)),
        out_shape=jax.ShapeDtypeStruct((S, Pp), jnp.float32),
        interpret=interpret,
    )(q_lo, q_hi, p_min, p_max)
    return out[:, :P]
