"""Pure-jnp oracle for the move-score kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


@jax.jit
def move_scores(q_lo: jax.Array, q_hi: jax.Array, p_min: jax.Array,
                p_max: jax.Array) -> jax.Array:
    """(Q, C) x (S, P, C) -> (S, P) float32 per-partition scan frequency.

    ``out[s, p]`` is the fraction of the Q queries that must scan
    partition p of state s — the quantity the micro-move planner turns
    into a benefit-per-row-moved ordering.
    """
    ov = ((p_min[None] <= q_hi[:, None, None, :])
          & (p_max[None] >= q_lo[:, None, None, :]))      # (Q, S, P, C)
    return ov.all(axis=-1).astype(jnp.float32).mean(axis=0)
