"""Jit'd wrapper: GQA-aware attention dispatching to the Pallas kernel.

On TPU the kernel path is compiled; on CPU the kernel runs in interpret mode
(tests) while production CPU paths use the blocked jnp implementation in
``repro.models.layers`` (identical math and blocking).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention as fa
from repro.kernels.flash_attention import ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def attention(q: jax.Array, k: jax.Array, v: jax.Array,
              causal: bool = True, prefix_len: int = 0,
              use_kernel: bool = True, **block_kw) -> jax.Array:
    """q: (B, T, Hq, dh); k, v: (B, S, Hkv, dh) -> (B, T, Hq, dh)."""
    B, T, Hq, dh = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    g = Hq // Hkv
    if g > 1:                         # expand kv heads for the MHA kernel
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(B * Hq, T, dh)
    kf = k.transpose(0, 2, 1, 3).reshape(B * Hq, S, dh)
    vf = v.transpose(0, 2, 1, 3).reshape(B * Hq, S, dh)
    if not use_kernel:
        out = ref.attention(qf, kf, vf, causal=causal, prefix_len=prefix_len)
    else:
        out = fa.flash_attention_pallas(qf, kf, vf, causal=causal,
                                        prefix_len=prefix_len,
                                        interpret=not _on_tpu(), **block_kw)
    return out.reshape(B, Hq, T, dh).transpose(0, 2, 1, 3)
