"""Pure-jnp oracle for the flash-attention kernel (exact softmax attention)."""
from __future__ import annotations


import jax
import jax.numpy as jnp


def attention(q: jax.Array, k: jax.Array, v: jax.Array,
              causal: bool = True,
              prefix_len: int = 0) -> jax.Array:
    """q: (BH, T, dh); k, v: (BH, S, dh) -> (BH, T, dh); exact softmax."""
    T, S = q.shape[1], k.shape[1]
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("btd,bsd->bts", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        qpos = jnp.arange(T)[:, None]
        kpos = jnp.arange(S)[None, :]
        mask = kpos <= qpos
        if prefix_len > 0:
            mask = mask | (kpos < prefix_len)
        s = jnp.where(mask[None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bts,bsd->btd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
