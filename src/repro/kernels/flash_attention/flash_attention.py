"""Pallas TPU kernel: blockwise online-softmax (flash) causal attention.

Canonical TPU structure: grid (batch*heads, n_q_blocks, n_kv_blocks) with the
kv dim innermost; running (max, denom, accumulator) live in VMEM scratch and
persist across kv grid steps; the output block is written on the last kv
step.  Block shapes are the hillclimb surface: (block_q, block_k) tiles must
be MXU-aligned (multiples of 128 on the lane dim) and sized so
q + k + v + acc fit VMEM (~16MB/core on v5e).

The jnp implementation in ``repro.models.layers.flash_attention`` mirrors
this blocking exactly; ``ops.py`` dispatches kernel-on-TPU / jnp-elsewhere.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 256
DEFAULT_BK = 256
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale, causal, prefix_len, bq, bk, nk):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    # Causal block skip: only compute blocks intersecting the mask.
    run = jnp.logical_or(not causal, ik * bk <= iq * bq + bq - 1)

    @pl.when(run)
    def _body():
        q = q_ref[0].astype(jnp.float32)              # (bq, dh)
        k = k_ref[0].astype(jnp.float32)              # (bk, dh)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale
        if causal:
            mask = k_pos <= q_pos
            if prefix_len > 0:
                mask = jnp.logical_or(mask, k_pos < prefix_len)
            s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        if causal:
            p = jnp.where(mask, p, 0.0)
        l_new = l_prev * corr + p.sum(axis=-1)
        v = v_ref[0].astype(jnp.float32)              # (bk, dh)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * corr[:, None] + pv
        m_ref[...] = m_new
        l_ref[...] = l_new

    @pl.when(ik == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "prefix_len", "bq",
                                             "bk", "interpret"))
def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                           causal: bool = True, prefix_len: int = 0,
                           bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
                           interpret: bool = True) -> jax.Array:
    """q: (BH, T, dh); k, v: (BH, S, dh) -> (BH, T, dh)."""
    BH, T, dh = q.shape
    S = k.shape[1]
    bq = min(bq, T)
    bk = min(bk, S)
    pad_q = (-T) % bq
    pad_k = (-S) % bk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0)))
    Tp, Sp = T + pad_q, S + pad_k
    nq, nk = Tp // bq, Sp // bk
    if pad_k and not causal:
        raise ValueError("non-causal padding needs explicit kv masking")
    out = pl.pallas_call(
        functools.partial(_kernel, scale=dh ** -0.5, causal=causal,
                          prefix_len=prefix_len, bq=bq, bk=bk, nk=nk),
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, dh), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, dh), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Tp, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),       # running max
            pltpu.VMEM((bq,), jnp.float32),       # running denom
            pltpu.VMEM((bq, dh), jnp.float32),    # accumulator
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :T]
