"""Shared kernel-backend plumbing for the Pallas kernels.

Every kernel in this package takes ``interpret: Optional[bool]`` and used
to copy-paste the same auto-detect: run the compiled Mosaic kernel when
JAX has an accelerator backend (TPU/GPU), fall back to the Pallas
interpreter on CPU-only hosts, where Mosaic lowering is unavailable but
the interpreter executes the identical program.  :func:`resolve_interpret`
is that logic in one place, so a new kernel (or a test monkeypatching the
detected backend) has exactly one seam to hit.
"""
from __future__ import annotations

from typing import Optional

import jax


def default_backend() -> str:
    """The JAX platform kernels run on (``"cpu"``, ``"tpu"``, ``"gpu"``).

    Thin indirection over :func:`jax.default_backend` so tests can
    monkeypatch the detected platform without touching global JAX state.
    """
    return jax.default_backend()


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """Resolve an ``interpret=None`` kernel argument to a concrete bool.

    ``None`` auto-selects: compiled Mosaic when an accelerator backend is
    available, the Pallas interpreter on CPU-only hosts.  An explicit
    ``True``/``False`` is passed through unchanged.
    """
    if interpret is None:
        return default_backend() == "cpu"
    return bool(interpret)
