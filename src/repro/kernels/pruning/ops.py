"""Jit'd public wrapper for the pruning kernel.

Dispatches to the Pallas kernel on TPU (compiled) and to interpret mode /
the jnp oracle elsewhere.  ``scan_fractions`` composes the kernel with the
row-count weighting used by the cost model.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.pruning import pruning, ref


def scan_matrix(q_lo, q_hi, p_min, p_max, use_kernel: bool = True,
                **block_kw) -> jax.Array:
    if not use_kernel:
        return ref.scan_matrix(q_lo, q_hi, p_min, p_max)
    # interpret auto-selected inside the kernel wrapper: compiled on
    # accelerator backends, interpreter on CPU-only hosts.
    return pruning.scan_matrix_pallas(q_lo, q_hi, p_min, p_max, **block_kw)


@jax.jit
def scan_fractions(q_lo, q_hi, p_min, p_max, rows) -> jax.Array:
    m = ref.scan_matrix(q_lo, q_hi, p_min, p_max)  # jnp path under jit
    total = jnp.maximum(rows.sum(), 1.0)
    return (m @ rows.astype(jnp.float32)) / total


def cost_vectors(q_lo, q_hi, layouts_meta, use_kernel: bool = True):
    """Batch cost vectors for several layouts (list of (min, max, rows))."""
    out = []
    for p_min, p_max, rows in layouts_meta:
        m = scan_matrix(q_lo, q_hi, jnp.asarray(p_min), jnp.asarray(p_max),
                        use_kernel=use_kernel)
        total = jnp.maximum(jnp.asarray(rows).sum(), 1.0)
        out.append((m @ jnp.asarray(rows, jnp.float32)) / total)
    return jnp.stack(out)
