"""Pallas TPU kernel: partition-pruning scan matrix (paper's eval_skipped).

The LAYOUT MANAGER evaluates every candidate layout against the R-TBS query
sample (cost vectors, Alg. 5) and the REORGANIZER scores every incoming query
against every state's metadata -- both reduce to the (Q, P) interval-overlap
matrix over C columns.  On TPU this is a VPU-bound elementwise-AND reduction:

  grid = (Q/BQ, P/BP); each program holds a (BQ, C) query tile and a (BP, C)
  partition tile in VMEM and accumulates the (BQ, BP) overlap AND over column
  chunks, so the (Q, P, C) broadcast tensor never materializes.

Arithmetic intensity ~ C flops/byte over metadata -- memory-bound; block
sizes keep the working set (2*BQ*C + 2*BP*C + BQ*BP floats) well under VMEM.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels._backend import resolve_interpret

DEFAULT_BQ = 128
DEFAULT_BP = 128


def _kernel(qlo_ref, qhi_ref, pmin_ref, pmax_ref, out_ref, *, col_chunk):
    qlo = qlo_ref[...]            # (BQ, C)
    qhi = qhi_ref[...]
    pmin = pmin_ref[...]          # (BP, C)
    pmax = pmax_ref[...]
    bq, c = qlo.shape
    bp = pmin.shape[0]
    acc = jnp.ones((bq, bp), jnp.float32)
    n_chunks = pl.cdiv(c, col_chunk)
    for i in range(n_chunks):
        lo = i * col_chunk
        width = min(col_chunk, c - lo)
        ql = jax.lax.dynamic_slice(qlo, (0, lo), (bq, width))
        qh = jax.lax.dynamic_slice(qhi, (0, lo), (bq, width))
        pn = jax.lax.dynamic_slice(pmin, (0, lo), (bp, width))
        px = jax.lax.dynamic_slice(pmax, (0, lo), (bp, width))
        ov = ((pn[None, :, :] <= qh[:, None, :])
              & (px[None, :, :] >= ql[:, None, :]))
        acc = acc * ov.all(axis=-1).astype(jnp.float32)
    out_ref[...] = acc


def scan_matrix_pallas(q_lo: jax.Array, q_hi: jax.Array, p_min: jax.Array,
                       p_max: jax.Array, bq: int = DEFAULT_BQ,
                       bp: int = DEFAULT_BP, col_chunk: int = 8,
                       interpret: Optional[bool] = None) -> jax.Array:
    """(Q, C) x (P, C) -> (Q, P) float32 scan matrix.

    ``interpret=None`` auto-selects: the compiled kernel when JAX has an
    accelerator backend (TPU/GPU), the Pallas interpreter on CPU-only hosts
    (where the Mosaic pipeline is unavailable).
    """
    return _scan_matrix_call(q_lo, q_hi, p_min, p_max, bq=bq, bp=bp,
                             col_chunk=col_chunk,
                             interpret=resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("bq", "bp", "col_chunk",
                                             "interpret"))
def _scan_matrix_call(q_lo: jax.Array, q_hi: jax.Array, p_min: jax.Array,
                      p_max: jax.Array, bq: int, bp: int, col_chunk: int,
                      interpret: bool) -> jax.Array:
    Q, C = q_lo.shape
    P = p_min.shape[0]
    bq = min(bq, Q)
    bp = min(bp, P)
    pad_q = (-Q) % bq
    pad_p = (-P) % bp
    if pad_q:
        q_lo = jnp.pad(q_lo, ((0, pad_q), (0, 0)), constant_values=1.0)
        q_hi = jnp.pad(q_hi, ((0, pad_q), (0, 0)), constant_values=0.0)
    if pad_p:
        p_min = jnp.pad(p_min, ((0, pad_p), (0, 0)), constant_values=1.0)
        p_max = jnp.pad(p_max, ((0, pad_p), (0, 0)), constant_values=0.0)
    Qp, Pp = Q + pad_q, P + pad_p
    grid = (Qp // bq, Pp // bp)
    out = pl.pallas_call(
        functools.partial(_kernel, col_chunk=col_chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, C), lambda i, j: (i, 0)),
            pl.BlockSpec((bq, C), lambda i, j: (i, 0)),
            pl.BlockSpec((bp, C), lambda i, j: (j, 0)),
            pl.BlockSpec((bp, C), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bq, bp), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Qp, Pp), jnp.float32),
        interpret=interpret,
    )(q_lo, q_hi, p_min, p_max)
    return out[:Q, :P]
