"""Pure-jnp oracle for the partition-pruning (eval_skipped) kernel.

Semantics match ``repro.core.layouts.partitions_scanned`` / ``eval_cost``:
a partition must be scanned iff every column's [min, max] zone overlaps the
query's [lo, hi] range.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def scan_matrix(q_lo: jax.Array, q_hi: jax.Array, p_min: jax.Array,
                p_max: jax.Array) -> jax.Array:
    """(Q, C), (Q, C), (P, C), (P, C) -> (Q, P) float32 in {0, 1}."""
    overlap = ((p_min[None, :, :] <= q_hi[:, None, :])
               & (p_max[None, :, :] >= q_lo[:, None, :]))       # (Q, P, C)
    return overlap.all(axis=-1).astype(jnp.float32)


def scan_fractions(q_lo: jax.Array, q_hi: jax.Array, p_min: jax.Array,
                   p_max: jax.Array, rows: jax.Array) -> jax.Array:
    """Fraction of data records accessed per query: (Q,) float32."""
    m = scan_matrix(q_lo, q_hi, p_min, p_max)
    total = jnp.maximum(rows.sum(), 1.0)
    return (m @ rows.astype(jnp.float32)) / total
