"""Jit'd public wrapper for the fused fleet-scan kernel.

Dispatches to the Pallas kernel on accelerator backends (compiled) /
interpret mode on CPU, and to the jnp oracle when the kernel is bypassed.
``fleet_scan_fractions`` composes the kernel with the per-tenant row-count
weighting used by the cost model.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.fleet_scan import fleet_scan, ref


def scan_fleet(q_lo, q_hi, p_min, p_max, use_kernel: bool = True,
               **block_kw) -> jax.Array:
    if not use_kernel:
        return ref.scan_fleet(q_lo, q_hi, p_min, p_max)
    return fleet_scan.scan_fleet_pallas(q_lo, q_hi, p_min, p_max, **block_kw)


@jax.jit
def fleet_scan_fractions(q_lo, q_hi, p_min, p_max, rows) -> jax.Array:
    """(T, N) scan matrix reduced to (T,) fraction-of-rows-read per tenant.

    ``rows`` is (T, N): per-slot row counts, zero in padded slots, so each
    tenant's fraction is sum(scanned rows) / sum(all rows).
    """
    m = ref.scan_fleet(q_lo, q_hi, p_min, p_max)   # jnp path under jit
    rows = rows.astype(jnp.float32)
    total = jnp.maximum(rows.sum(axis=1), 1.0)
    return (m * rows).sum(axis=1) / total
