"""Pallas TPU kernel: fused multi-tenant fleet scan matrix.

One level above :mod:`repro.kernels.pruning`: instead of one query against
one table's partition bounds, the fleet decision plane scores *every
tenant's* current query against *that tenant's* packed candidate states in
a single launch.  Inputs are the packed fleet plane (T, N, C) — N =
S_max * P_max flattened state-x-partition slots, padded slots carrying
[+inf, -inf] bounds so they never overlap — and per-tenant query bounds
(T, C); the output is the (T, N) overlap matrix.

  grid = (T/BT, N/BN); each program holds a (BT, C) query tile and its
  matching (BT, BN, C) bounds tile in VMEM and accumulates the (BT, BN)
  overlap AND over column chunks, so the (T, N, C) broadcast tensor never
  materializes.  The tenant axis rides the sublane dimension: every lane
  still does the same elementwise compare, only against its own tenant's
  query row — this is what fuses T kernel launches into one.

Like the single-table kernel this is VPU-bound and memory-bound (~C
flops/byte over metadata); block sizes keep the working set
(2*BT*C + 2*BT*BN*C + BT*BN floats) well under VMEM.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels._backend import resolve_interpret

DEFAULT_BT = 8
DEFAULT_BN = 128


def _kernel(qlo_ref, qhi_ref, pmin_ref, pmax_ref, out_ref, *, col_chunk):
    qlo = qlo_ref[...]            # (BT, C)
    qhi = qhi_ref[...]
    pmin = pmin_ref[...]          # (BT, BN, C)
    pmax = pmax_ref[...]
    bt, c = qlo.shape
    bn = pmin.shape[1]
    acc = jnp.ones((bt, bn), jnp.float32)
    n_chunks = pl.cdiv(c, col_chunk)
    for i in range(n_chunks):
        lo = i * col_chunk
        width = min(col_chunk, c - lo)
        ql = jax.lax.dynamic_slice(qlo, (0, lo), (bt, width))
        qh = jax.lax.dynamic_slice(qhi, (0, lo), (bt, width))
        pn = jax.lax.dynamic_slice(pmin, (0, 0, lo), (bt, bn, width))
        px = jax.lax.dynamic_slice(pmax, (0, 0, lo), (bt, bn, width))
        ov = ((pn <= qh[:, None, :]) & (px >= ql[:, None, :]))
        acc = acc * ov.all(axis=-1).astype(jnp.float32)
    out_ref[...] = acc


def scan_fleet_pallas(q_lo: jax.Array, q_hi: jax.Array, p_min: jax.Array,
                      p_max: jax.Array, bt: int = DEFAULT_BT,
                      bn: int = DEFAULT_BN, col_chunk: int = 8,
                      interpret: Optional[bool] = None) -> jax.Array:
    """(T, C) per-tenant bounds x (T, N, C) plane -> (T, N) float32 matrix.

    ``out[t, n]`` is 1.0 iff slot n of tenant t's packed plane overlaps
    tenant t's query on every column.  ``interpret=None`` auto-selects: the
    compiled kernel when JAX has an accelerator backend (TPU/GPU), the
    Pallas interpreter on CPU-only hosts.
    """
    return _scan_fleet_call(q_lo, q_hi, p_min, p_max, bt=bt, bn=bn,
                            col_chunk=col_chunk,
                            interpret=resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("bt", "bn", "col_chunk",
                                             "interpret"))
def _scan_fleet_call(q_lo: jax.Array, q_hi: jax.Array, p_min: jax.Array,
                     p_max: jax.Array, bt: int, bn: int, col_chunk: int,
                     interpret: bool) -> jax.Array:
    T, C = q_lo.shape
    N = p_min.shape[1]
    bt = min(bt, T)
    bn = min(bn, N)
    pad_t = (-T) % bt
    pad_n = (-N) % bn
    if pad_t:
        # Padded tenant rows get empty queries ([1, 0] per column) so their
        # outputs are 0 and sliced away.
        q_lo = jnp.pad(q_lo, ((0, pad_t), (0, 0)), constant_values=1.0)
        q_hi = jnp.pad(q_hi, ((0, pad_t), (0, 0)), constant_values=0.0)
        p_min = jnp.pad(p_min, ((0, pad_t), (0, 0), (0, 0)),
                        constant_values=1.0)
        p_max = jnp.pad(p_max, ((0, pad_t), (0, 0), (0, 0)),
                        constant_values=0.0)
    if pad_n:
        # Padded slots get empty bounds: never scanned, for any query.
        p_min = jnp.pad(p_min, ((0, 0), (0, pad_n), (0, 0)),
                        constant_values=1.0)
        p_max = jnp.pad(p_max, ((0, 0), (0, pad_n), (0, 0)),
                        constant_values=0.0)
    Tp, Np = T + pad_t, N + pad_n
    grid = (Tp // bt, Np // bn)
    out = pl.pallas_call(
        functools.partial(_kernel, col_chunk=col_chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, C), lambda i, j: (i, 0)),
            pl.BlockSpec((bt, C), lambda i, j: (i, 0)),
            pl.BlockSpec((bt, bn, C), lambda i, j: (i, j, 0)),
            pl.BlockSpec((bt, bn, C), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((bt, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Tp, Np), jnp.float32),
        interpret=interpret,
    )(q_lo, q_hi, p_min, p_max)
    return out[:T, :N]
