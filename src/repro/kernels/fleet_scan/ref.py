"""Pure-jnp oracle for the fused fleet scan kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


@jax.jit
def scan_fleet(q_lo: jax.Array, q_hi: jax.Array, p_min: jax.Array,
               p_max: jax.Array) -> jax.Array:
    """(T, C) x (T, N, C) -> (T, N) float32 overlap matrix (broadcasting)."""
    ov = ((p_min <= q_hi[:, None, :]) & (p_max >= q_lo[:, None, :]))
    return ov.all(axis=-1).astype(jnp.float32)
