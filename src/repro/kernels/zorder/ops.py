"""Jit'd wrapper for the Z-order kernel (TPU kernel / interpret fallback)."""
from __future__ import annotations

import jax

from repro.kernels.zorder import ref, zorder


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def zorder_keys(values, lo, hi, bits: int = 10,
                use_kernel: bool = True) -> jax.Array:
    if not use_kernel:
        return ref.zorder_keys(values, lo, hi, bits)
    return zorder.zorder_keys_pallas(values, lo, hi, bits=bits,
                                     interpret=not _on_tpu())
