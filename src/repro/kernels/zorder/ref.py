"""Pure-jnp oracle for Z-order (Morton) bit interleaving."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def interleave(codes: jax.Array, bits: int) -> jax.Array:
    """(N, m) uint32 codes (each < 2**bits) -> (N,) uint32 Morton keys.

    Bit b of column j lands at position b*m + j.  Requires m*bits <= 32.
    """
    n, m = codes.shape
    assert m * bits <= 32 and bits <= 16, (m, bits)
    keys = jnp.zeros(n, jnp.uint32)
    codes = codes.astype(jnp.uint32)
    for b in range(bits):
        for j in range(m):
            bit = (codes[:, j] >> jnp.uint32(b)) & jnp.uint32(1)
            keys = keys | (bit << jnp.uint32(b * m + j))
    return keys


def quantize(values: jax.Array, lo: jax.Array, hi: jax.Array,
             bits: int) -> jax.Array:
    """Linear-quantize (N, m) float columns to ``bits``-bit codes."""
    span = jnp.maximum(hi - lo, 1e-12)
    q = jnp.clip((values - lo) / span, 0.0, 1.0)
    return (q * ((1 << bits) - 1)).astype(jnp.uint32)


def zorder_keys(values: jax.Array, lo: jax.Array, hi: jax.Array,
                bits: int = 10) -> jax.Array:
    return interleave(quantize(values, lo, hi, bits), bits)
