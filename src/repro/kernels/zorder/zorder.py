"""Pallas TPU kernel: Z-order (Morton) key computation.

Z-order reorganization quantizes the top-queried columns and sorts rows by
interleaved-bit keys; at reorganization time this runs over every row of the
table, so the quantize+interleave inner loop is the bandwidth-bound hot spot
(the sort itself is XLA's).  Integer VPU work, tiled (BN, m) blocks in VMEM;
the bit loop is fully unrolled (bits * m iterations of shift/mask/or).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BN = 1024


def _kernel(vals_ref, lo_ref, hi_ref, out_ref, *, bits):
    vals = vals_ref[...]                    # (BN, m) f32
    lo = lo_ref[...]                        # (1, m)
    hi = hi_ref[...]
    n, m = vals.shape
    span = jnp.maximum(hi - lo, 1e-12)
    q = jnp.clip((vals - lo) / span, 0.0, 1.0)
    codes = (q * ((1 << bits) - 1)).astype(jnp.uint32)
    keys = jnp.zeros((n,), jnp.uint32)
    for b in range(bits):
        for j in range(m):
            bit = (codes[:, j] >> jnp.uint32(b)) & jnp.uint32(1)
            keys = keys | (bit << jnp.uint32(b * m + j))
    out_ref[...] = keys


@functools.partial(jax.jit, static_argnames=("bits", "bn", "interpret"))
def zorder_keys_pallas(values: jax.Array, lo: jax.Array, hi: jax.Array,
                       bits: int = 10, bn: int = DEFAULT_BN,
                       interpret: bool = True) -> jax.Array:
    """(N, m) float columns -> (N,) uint32 Morton keys (m*bits <= 32)."""
    N, m = values.shape
    assert m * bits <= 32 and bits <= 16, (m, bits)
    bn = min(bn, N)
    pad = (-N) % bn
    if pad:
        values = jnp.pad(values, ((0, pad), (0, 0)))
    lo2 = lo.reshape(1, m).astype(jnp.float32)
    hi2 = hi.reshape(1, m).astype(jnp.float32)
    out = pl.pallas_call(
        functools.partial(_kernel, bits=bits),
        grid=((N + pad) // bn,),
        in_specs=[
            pl.BlockSpec((bn, m), lambda i: (i, 0)),
            pl.BlockSpec((1, m), lambda i: (0, 0)),
            pl.BlockSpec((1, m), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bn,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((N + pad,), jnp.uint32),
        interpret=interpret,
    )(values.astype(jnp.float32), lo2, hi2)
    return out[:N]
