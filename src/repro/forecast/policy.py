"""ForecastPolicy: α-safe predictive wrapper around the reactive OREO loop.

Wraps an :class:`repro.engine.policies.OreoPolicy` and adds two predictive
behaviors on top of its unchanged reactive machinery:

* **Pre-positioning** — when the forecaster predicts a regime whose best
  layout differs from the current decision state and the predicted saving
  justifies the price (``saving_per_query * dwell > margin * α``), the
  policy deterministically moves the D-UMTS to that state
  (:meth:`repro.core.mts.DynamicUMTS.force_move`) and charges a normal
  α-priced, Δ-delayed reorganization through the engine — the identical
  governor/scheduler/micro-move path reactive jumps take, so every safety
  property of that path (charge ledgers, deferral semantics, incremental
  execution) carries over untouched.
* **State growth** — new forecasts are offered to a
  :class:`repro.forecast.grower.QdTreeGrower`; admitted layouts join the
  D-UMTS state space and the backend's StateMatrix plane mid-run (the
  dynamic-state events every mirror already consumes).

**The worst-case envelope.**  Pre-positioning spend is hard-clamped:
a new pre-position is allowed only while

    ``prepositions + 1 <= budget_frac * reactive_moves``

so cumulative pre-position charges never exceed ``budget_frac`` of what
the reactive policy is provably allowed to spend (OReO's Theorem IV.1
envelope) — an always-wrong forecaster degrades the trace by at most a
constant factor of the reactive movement budget, never unboundedly.
Each wrong pre-position additionally costs at most α of excess query
cost before the mispredicted state's counter fills plus one α corrective
jump, both already accounted by the D-UMTS analysis.  With
``budget_frac=0`` and ``grow=False`` the wrapper consumes no randomness
and issues no moves: the trace is *bitwise identical* to the bare inner
policy (golden-tested).

The wrapper is picklable and deterministic; it deliberately does **not**
implement ``decide_frames``, so the fleet's batched path primes costs
per event and falls back to the exact per-event machinery — loop and
``run_batched`` traces stay bit-identical even while grown states churn
the plane mid-stream (plane-version checks invalidate stale primes).
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.core import layouts, workload as wl
from repro.engine.policies import Decision

from .grower import QdTreeGrower
from .predictors import EwmaMixtureForecaster, Forecast, template_key


@dataclasses.dataclass
class ForecastConfig:
    """Knobs of the predictive plane (the α-safety clamp included)."""

    lead: int = 16              # steps ahead forecasts target
    forecast_every: int = 10    # recompute the forecast every N queries
    #: Pre-position only when ``saving_per_query * dwell > margin * α``.
    margin: float = 0.5
    #: Margin for trend-source forecasts.  A trend fires mid-drift where
    #: the mixture shifts a little every horizon — per-event savings are
    #: structurally smaller than at a periodic phase boundary, so the
    #: same bar would suppress exactly the moves drift forecasting is
    #: for; the mixture-weighted scoring already discounts the upside.
    trend_margin: float = 0.25
    #: Hard clamp: prepositions+1 <= budget_frac * reactive_moves.  0
    #: disables pre-positioning entirely (bitwise-reactive trace).
    budget_frac: float = 1.0
    min_gap: int = 8            # min queries between pre-positions
    grow: bool = True           # offer forecasts to the qd-tree grower
    #: Forecast sources eligible for growth.  Periodic forecasts describe
    #: *recurring* regimes the reactive LayoutManager has already seen and
    #: covered from its window, so growing for them just dilutes the
    #: D-UMTS (every active state's counter accrues on every query);
    #: trend forecasts describe *novel* rising regimes the window hasn't
    #: caught up with yet — the gap growth exists to close.
    grow_sources: Tuple[str, ...] = ("trend", "adversarial")
    max_grown: int = 3          # live grown states per tenant
    grow_min_queries: int = 8   # forecast sample floor for growing
    grow_gain: float = 0.25     # held-out relative-cost bar for admission
    grow_cost_floor: float = 0.15   # best-existing cost bar for admission
    #: Retire a grown state once the decision plane hasn't selected it
    #: for this many queries — an idle grown state is pure D-UMTS
    #: dilution (its counter still accrues on every query).
    grow_retire_after: int = 256


class ForecastPolicy:
    """Predictive decision layer over an inner (reactive) OREO policy.

    ``inner`` must expose the OreoPolicy surface (``dumts``, ``manager``,
    ``config``, ``bind``/``decide``/``info``); the default forecaster is
    an :class:`repro.forecast.predictors.EwmaMixtureForecaster` and the
    default grower builds qd-trees over the inner manager's table.
    """

    def __init__(self, inner, forecaster=None,
                 config: Optional[ForecastConfig] = None,
                 grower: Optional[QdTreeGrower] = None):
        self.inner = inner
        self.config = config or ForecastConfig()
        self.alpha = inner.alpha
        self.name = f"Forecast+{inner.name}"
        self.forecaster = forecaster or EwmaMixtureForecaster()
        mgr = getattr(inner, "manager", None)
        if grower is None and mgr is not None:
            grower = QdTreeGrower(
                mgr.data, mgr.config.target_partitions,
                min_queries=self.config.grow_min_queries,
                gain=self.config.grow_gain,
                cost_floor=self.config.grow_cost_floor,
                alpha=inner.alpha,
                seed=getattr(inner.config, "seed", 0) + 101)
        self.grower = grower

        self._fc: Optional[Forecast] = None
        self._fc_bounds: Optional[Tuple[np.ndarray, np.ndarray]] = None
        self._pred_cost: Dict[int, float] = {}
        self._grown: List[int] = []         # live grown ids, oldest first
        self._grown_key: Dict[int, Tuple] = {}   # grown id -> forecast key
        self._grown_used: Dict[int, int] = {}    # grown id -> last current
        self._pending_checks: Deque[Tuple[int, Tuple]] = collections.deque()
        self._last_pre = -(10 ** 9)
        self._index = -1
        #: Per-target cooldown: after pre-positioning to a state, don't
        #: pre-position to it again for ~one regime dwell.  If the move
        #: was wrong and the reactive machinery jumped away, retrying the
        #: same target immediately is the ping-pong the clamp should not
        #: have to absorb; if it was right, there is nothing to retry.
        self._cooldown: Dict[int, int] = {}
        self.num_forecasts = 0
        self.prepositions = 0
        self.forecast_checks = 0
        self.forecast_hits = 0

    # ------------------------------------------------------------------
    @property
    def reactive_moves(self) -> int:
        """Moves the inner D-UMTS made on its own (the envelope anchor)."""
        return self.inner.dumts.num_moves - self.prepositions

    def bind(self, backend) -> int:
        return self.inner.bind(backend)

    # ------------------------------------------------------------------
    def _predicted_cost(self, sid: int, backend) -> float:
        c = self._pred_cost.get(sid)
        if c is None:
            q_lo, q_hi = self._fc_bounds
            c = float(layouts.eval_cost(backend.get(sid).meta,
                                        q_lo, q_hi).mean())
            self._pred_cost[sid] = c
        return c

    def _maybe_grow(self, fc: Forecast, backend) -> None:
        dumts = self.inner.dumts
        if fc.source not in self.config.grow_sources:
            return
        if any(self._grown_key.get(g) == fc.key for g in self._grown):
            return      # this regime already has a live grown layout
        existing = [backend.get(s).meta for s in sorted(dumts.states)
                    if backend.has(s)]
        cand = self.grower.propose(fc, existing)
        if cand is None:
            return
        # Defer activation to the next phase reset: a mid-phase grown
        # state is a preferred jump target (unseen states score an
        # optimistic transition weight) for a regime that hasn't arrived.
        dumts.add_state(cand.layout_id, admission="defer")
        backend.register(cand)
        self._grown.append(cand.layout_id)
        self._grown_key[cand.layout_id] = fc.key
        self._grown_used[cand.layout_id] = self._index
        while len(self._grown) > self.config.max_grown:
            victim = next((g for g in self._grown
                           if g != dumts.current_state), None)
            if victim is None:
                break
            self._drop_grown(victim, backend)

    def _drop_grown(self, sid: int, backend) -> None:
        self._grown.remove(sid)
        self._grown_key.pop(sid, None)
        self._grown_used.pop(sid, None)
        self.inner.dumts.remove_state(sid)
        backend.deregister(sid)

    def _retire_idle_grown(self, index: int, backend) -> None:
        """Evict grown states the decision plane has stopped choosing.

        Once the reactive LayoutManager catches up with a drift (its
        window now *observes* the regime the forecast anticipated), its
        own candidate supersedes the grown layout — which then sits in
        the state space accruing counter mass on every query and
        fattening every jump distribution, paying for nothing.
        """
        limit = self.config.grow_retire_after
        cur = self.inner.dumts.current_state
        for sid in list(self._grown):
            if sid == cur:
                continue
            if index - self._grown_used.get(sid, index) > limit:
                self._drop_grown(sid, backend)

    # ------------------------------------------------------------------
    def decide(self, index: int, query: wl.Query, backend) -> Decision:
        cfg = self.config
        realized = template_key(query)
        while self._pending_checks and self._pending_checks[0][0] <= index:
            _, predicted = self._pending_checks.popleft()
            self.forecast_checks += 1
            if predicted == realized:
                self.forecast_hits += 1

        self.forecaster.observe(query)
        self._index = index
        if (index + 1) % cfg.forecast_every == 0:
            if cfg.grow and self.grower is not None:
                self._retire_idle_grown(index, backend)
            fc = self.forecaster.forecast(cfg.lead)
            if fc is not None:
                self._fc = fc
                self._fc_bounds = wl.stack_queries(fc.queries)
                self._pred_cost = {}
                self.num_forecasts += 1
                # fc.lead is the *effective* lead (forecasters clamp the
                # requested lead to the observed regime scale) — score
                # accuracy at the horizon actually predicted.
                self._pending_checks.append((index + fc.lead, fc.key))
                if cfg.grow and self.grower is not None:
                    self._maybe_grow(fc, backend)

        d = self.inner.decide(index, query, backend)
        if d.state in self._grown_used:
            self._grown_used[d.state] = index

        fc = self._fc
        if fc is None or d.reorg or fc.key == realized:
            # Only act while the prediction differs from what is realized
            # *now*: mid-regime there is nothing to pre-position for, and
            # once the predicted regime arrives the reactive machinery is
            # already looking at its true costs.
            return d
        dumts = self.inner.dumts
        cand = [s for s in dumts.active if backend.has(s)]
        if len(cand) < 2 or d.state not in cand:
            return d
        # Deterministic argmin over predicted per-query cost; ties break
        # to the smallest state id (tuple order).
        best_cost, best_sid = min(
            (self._predicted_cost(s, backend), s) for s in sorted(cand))
        saving = self._predicted_cost(d.state, backend) - best_cost
        # Counters accrue on *every* active state, so a target whose
        # counter is nearly full gets force-retired by the D-UMTS almost
        # immediately — its remaining headroom caps how long the
        # pre-position can actually hold, whatever the forecast's dwell.
        headroom = self.alpha - dumts.counters.get(best_sid, 0.0)
        dwell = min(fc.dwell, headroom / max(best_cost, 1e-6))
        margin = cfg.trend_margin if fc.source == "trend" else cfg.margin
        if (best_sid != d.state
                and saving * dwell > margin * self.alpha
                and index - self._last_pre >= cfg.min_gap
                and index >= self._cooldown.get(best_sid, -1)
                and self.prepositions + 1
                    <= cfg.budget_frac * self.reactive_moves):
            dumts.force_move(best_sid)
            self.prepositions += 1
            self._last_pre = index
            self._cooldown[best_sid] = index + max(cfg.min_gap,
                                                   int(fc.dwell))
            return Decision(state=best_sid, reorg=True,
                            added=d.added, removed=d.removed)
        return d

    # ------------------------------------------------------------------
    def info(self) -> dict:
        out = dict(self.inner.info())
        out.update(self.forecaster.info())
        if self.grower is not None:
            out.update(self.grower.info())
        out.update({
            "forecasts": self.num_forecasts,
            "prepositions": self.prepositions,
            "reactive_moves": self.reactive_moves,
            "forecast_checks": self.forecast_checks,
            "forecast_hits": self.forecast_hits,
            "forecast_accuracy": (self.forecast_hits / self.forecast_checks
                                  if self.forecast_checks else None),
        })
        return out
