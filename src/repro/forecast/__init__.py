"""The predictive decision plane: forecast → grow → pre-position.

Dataflow (each layer optional and independently testable)::

    per-tenant query stream
        │ observe
        ▼
    EwmaMixtureForecaster ──────────► Forecast (key, queries, dwell)
    (period detector + EWMA trend)        │                │
                                          ▼                ▼
                              QdTreeGrower.propose   ForecastPolicy
                              (online state growth)  (α-safe pre-position)
                                          │                │
                                          ▼                ▼
                          StateMatrix register/      DynamicUMTS.force_move
                          deregister events          + α-charged Δ-delayed
                          (FleetMatrix mirrors,      reorg through the
                          fused-kernel planes,       engine/governor path
                          serve caches stay exact)

Everything here is pure, deterministic and picklable; the reactive OREO
envelope is the safety net (see :class:`ForecastPolicy`'s clamp).
"""
from .grower import GROWN_ID_BASE, QdTreeGrower, grown_ids
from .policy import ForecastConfig, ForecastPolicy
from .predictors import (AdversarialForecaster, EwmaMixtureForecaster,
                         Forecast, PeriodDetector, template_key)

__all__ = [
    "AdversarialForecaster", "EwmaMixtureForecaster", "Forecast",
    "ForecastConfig", "ForecastPolicy", "GROWN_ID_BASE", "PeriodDetector",
    "QdTreeGrower", "grown_ids", "template_key",
]
