"""Online qd-tree state growth from forecasted query distributions.

The LayoutManager (Algorithm 5) generates candidates from the *observed*
sliding window — by the time a drifted template dominates the window, the
fleet has already paid for the transition.  :class:`QdTreeGrower` closes
that gap: given a :class:`repro.forecast.predictors.Forecast`, it builds
a qd-tree layout (Yang et al., SIGMOD'20 — the same
:func:`repro.core.qdtree.build_qdtree_layout` the reactive generator
uses) over the *predicted* query sample and admits it only when its
predicted mean cost undercuts every already-registered state by a
relative margin — learned cost estimates over the forecast window, in
the spirit of cost-estimation-driven partitioning.

Grown state ids live in their own id space (:data:`GROWN_ID_BASE`) so
they can never collide with LayoutManager candidates; like the manager,
the grower only consumes an id on admission (a rejected candidate's id
is reused by the next proposal).  Registration and eviction are the
caller's job (:class:`repro.forecast.policy.ForecastPolicy` routes them
through ``dumts.add_state``/``remove_state`` + backend
register/deregister, i.e. the StateMatrix dynamic-state events every
mirror — FleetMatrix twins, fused-kernel planes, serve caches — already
listens to).
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import numpy as np

from repro.core import layouts, qdtree, workload as wl

from .predictors import Forecast

#: Grown layout ids start here — disjoint from LayoutManager's
#: ``next_id`` counter (initial layout id + admissions) by a wide margin.
GROWN_ID_BASE = 1_000_000


class QdTreeGrower:
    """Propose qd-tree layouts for forecasted workloads; picklable."""

    def __init__(self, data: np.ndarray, target_partitions: int,
                 min_queries: int = 8, gain: float = 0.25,
                 cost_floor: float = 0.15, alpha: float = 0.0,
                 admit_margin: float = 1.0, seed: int = 0):
        self.data = data
        self.target_partitions = int(target_partitions)
        #: Minimum forecast sample size worth building a tree over.
        self.min_queries = int(min_queries)
        #: Relative held-out predicted-cost improvement for admission.
        self.gain = float(gain)
        #: Absolute bar: grow only when the best existing state still
        #: scans at least this fraction on the predicted regime.
        self.cost_floor = float(cost_floor)
        #: The D-UMTS movement cost the state space operates under.  A
        #: grown state the decision plane ever visits inserts an extra
        #: α-priced hop in the jump sequence, so admission must predict
        #: a payoff that covers it: ``(best - cand) * dwell >
        #: admit_margin * alpha``.  At ``alpha=0`` the test is void.
        self.alpha = float(alpha)
        self.admit_margin = float(admit_margin)
        self.seed = int(seed)
        self.next_id = GROWN_ID_BASE
        self.num_proposed = 0
        self.num_admitted = 0

    def propose(self, fc: Forecast,
                existing_metas: Sequence[layouts.PartitionMetadata],
                ) -> Optional[layouts.Layout]:
        """Build and vet one candidate for the forecast; None if rejected.

        The tree is built on *half* the forecast sample and vetted on the
        held-out half — scoring on the training queries would admit every
        tree (a qd-tree trivially crushes the exact predicates it was cut
        from), flooding the D-UMTS with near-duplicates whose counters
        dilute the α budget (every active state accrues on every query).
        Admission requires the held-out mean cost to undercut the best
        existing state by ``gain`` relative *and* that best existing cost
        to exceed ``cost_floor`` — a regime some registered layout already
        serves cheaply is not worth another state.
        """
        if len(fc.queries) < self.min_queries:
            return None
        self.num_proposed += 1
        train = fc.queries[::2]
        test = fc.queries[1::2]
        q_lo, q_hi = wl.stack_queries(test)
        best = min(
            (float(layouts.eval_cost(m, q_lo, q_hi).mean())
             for m in existing_metas), default=np.inf)
        if best <= self.cost_floor:
            return None
        cand = qdtree.build_qdtree_layout(
            self.next_id, self.data, train, self.target_partitions,
            seed=self.seed, name=f"grown#{self.next_id}")
        cand_cost = float(layouts.eval_cost(cand.meta, q_lo, q_hi).mean())
        if cand_cost >= (1.0 - self.gain) * best:
            return None                     # id reused by the next proposal
        if (best - cand_cost) * fc.dwell <= self.admit_margin * self.alpha:
            return None                     # payoff won't cover the α hop
        self.next_id += 1
        self.num_admitted += 1
        return cand

    def info(self) -> dict:
        return {"grown_proposed": self.num_proposed,
                "grown_admitted": self.num_admitted}


def grown_ids(state_ids) -> List[int]:
    """The subset of ``state_ids`` minted by a grower."""
    return [s for s in state_ids if s >= GROWN_ID_BASE]
