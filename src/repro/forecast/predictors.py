"""Workload forecasters: predict the next horizon's query distribution.

The decision plane below this module is purely *reactive*: D-UMTS only
moves once realized costs have filled a counter, so cyclic and
gradually-drifting workloads pay full query cost until the drift has been
observed.  A forecaster watches the same per-tenant query stream the
policy sees and emits a :class:`Forecast` — a predicted dominant template
for the next horizon plus a representative query sample for it — which
:class:`repro.forecast.policy.ForecastPolicy` turns into α-charged
pre-positioning moves and :class:`repro.forecast.grower.QdTreeGrower`
turns into new candidate layouts.

Every forecaster here is pure, deterministic and picklable (plain
attributes, no closures, no rng): engines holding one survive
cross-process tenant migration, and a fleet trace with forecasting
enabled is reproducible bit-for-bit.

Two predictors:

* :class:`EwmaMixtureForecaster` — the real one.  Tracks the template-key
  sequence (ground-truth ``template_id`` when the workload carries one,
  else the set of predicate columns), detects *periodic* recurrence by
  autocorrelation over the key codes (cyclic/diurnal workloads), and
  falls back to a half-window EWMA-style *trend* test (share of the
  rising key projected ``lead`` steps ahead) for monotone drift.
* :class:`AdversarialForecaster` — the always-wrong probe for the
  worst-case golden tests: it predicts the *mirror image* of the observed
  predicate ranges (so its predictions look confidently actionable) under
  a sentinel key that never matches a realized query.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.core import workload as wl


def template_key(query: wl.Query) -> Tuple:
    """Hashable regime key for a query.

    Workload generators stamp ``template_id``; ad-hoc queries fall back
    to the set of columns carrying a finite predicate, which is exactly
    what distinguishes the registry's template families from one another.
    """
    if query.template_id >= 0:
        return ("tpl", int(query.template_id))
    finite = np.flatnonzero(np.isfinite(query.lo) | np.isfinite(query.hi))
    return ("cols",) + tuple(int(c) for c in finite)


@dataclasses.dataclass
class Forecast:
    """One prediction for the next horizon of a tenant's stream.

    ``key`` is the predicted dominant template key ``lead`` steps ahead;
    ``queries`` is a representative sample of what those queries should
    look like (consumed by the grower and by predicted-cost scoring);
    ``dwell`` is the expected persistence (in queries) of the predicted
    regime once it arrives — the lever that decides whether an α-priced
    pre-position can ever pay for itself.
    """

    key: Tuple
    queries: List[wl.Query]
    source: str                 # "period" | "trend" | "adversarial"
    confidence: float           # in [0, 1]
    dwell: float                # expected regime persistence, in queries
    lead: int                   # steps ahead the prediction targets


class PeriodDetector:
    """Smallest period whose key-code autocorrelation clears a threshold.

    Operates on integer key codes; a period ``p`` matches when
    ``codes[i] == codes[i - p]`` for at least ``threshold`` of the
    overlapping positions.  Degenerate histories (fewer than two distinct
    keys) match *every* lag, so they are rejected outright — a constant
    workload needs no forecasting.
    """

    def __init__(self, period_min: int = 4, period_max: int = 384,
                 threshold: float = 0.85, min_history: int = 32):
        self.period_min = int(period_min)
        self.period_max = int(period_max)
        self.threshold = float(threshold)
        self.min_history = int(min_history)

    def detect(self, codes: np.ndarray) -> Optional[Tuple[int, float]]:
        """(period, match_fraction) of the smallest qualifying period."""
        n = codes.shape[0]
        if n < self.min_history or np.unique(codes).size < 2:
            return None
        hi = min(self.period_max, n // 2)
        for p in range(self.period_min, hi + 1):
            frac = float(np.mean(codes[p:] == codes[:-p]))
            if frac >= self.threshold:
                return p, frac
        return None


def _run_length(codes: np.ndarray) -> float:
    """Average length of maximal runs of identical consecutive codes."""
    if codes.size == 0:
        return 1.0
    changes = int(np.count_nonzero(codes[1:] != codes[:-1]))
    return codes.size / (changes + 1)


class EwmaMixtureForecaster:
    """Template-mixture forecaster: period detection + EWMA-trend fallback.

    Keeps a bounded history of template keys and, per key, a bounded
    sample of recent concrete queries.  :meth:`forecast` first looks for
    periodic recurrence (cyclic/diurnal workloads: the predicted key is
    read straight off the detected cycle ``lead`` steps ahead); failing
    that, it projects the half-window share trend of the fastest-rising
    key (gradual drift: fire once the projected share crosses a majority
    of the mix).  Returns None when neither signal clears its bar —
    single-template and erratic workloads produce no forecasts, so a
    wrapping policy falls through to pure reactive behavior.
    """

    name = "ewma-mixture"

    def __init__(self, history: int = 768, samples_per_key: int = 32,
                 period_min: int = 4, period_max: int = 384,
                 period_threshold: float = 0.85,
                 trend_window: int = 256, trend_share: float = 0.55,
                 trend_min_delta: float = 0.04, trend_dwell: float = 256.0,
                 ewma_lambda: float = 0.02):
        self.history = int(history)
        self.samples_per_key = int(samples_per_key)
        self.detector = PeriodDetector(period_min, period_max,
                                       period_threshold)
        self.trend_window = int(trend_window)
        self.trend_share = float(trend_share)
        self.trend_min_delta = float(trend_min_delta)
        self.trend_dwell = float(trend_dwell)
        self.ewma_lambda = float(ewma_lambda)
        self._code_of: Dict[Tuple, int] = {}
        self._codes: Deque[int] = collections.deque(maxlen=self.history)
        self._samples: Dict[int, Deque[wl.Query]] = {}
        self._shares: Dict[int, float] = {}     # EWMA mixture weights
        self.observed = 0

    # ------------------------------------------------------------------
    def observe(self, query: wl.Query) -> None:
        key = template_key(query)
        code = self._code_of.get(key)
        if code is None:
            code = len(self._code_of)
            self._code_of[key] = code
            self._samples[code] = collections.deque(
                maxlen=self.samples_per_key)
        self._codes.append(code)
        self._samples[code].append(query)
        lam = self.ewma_lambda
        for c in self._shares:
            self._shares[c] *= (1.0 - lam)
        self._shares[code] = self._shares.get(code, 0.0) + lam
        self.observed += 1

    # ------------------------------------------------------------------
    def _key_of_code(self, code: int) -> Tuple:
        for k, c in self._code_of.items():
            if c == code:
                return k
        raise KeyError(code)

    def forecast(self, lead: int = 20) -> Optional[Forecast]:
        codes = np.fromiter(self._codes, dtype=np.int64,
                            count=len(self._codes))
        n = codes.shape[0]
        if n < self.detector.min_history or np.unique(codes).size < 2:
            return None

        hit = self.detector.detect(codes)
        if hit is not None:
            p, frac = hit
            dwell = _run_length(codes)
            # A lead beyond half a regime block predicts *past* the next
            # boundary: the pre-positioned state then serves the tail of
            # the old regime long enough for its counter to fill and
            # force a reactive jump straight back (ping-pong).  Clamp to
            # the observed block scale.
            lead = max(1, min(lead, int(dwell // 2)))
            j = n - 1 + lead
            while j >= n:
                j -= p
            code = int(codes[j])
            qs = list(self._samples.get(code, ()))
            if qs:
                return Forecast(key=self._key_of_code(code), queries=qs,
                                source="period", confidence=frac,
                                dwell=dwell, lead=lead)

        w = min(n, self.trend_window)
        recent = codes[-w:]
        half = w // 2
        if half < 8:
            return None
        first, second = recent[:half], recent[half:]
        counts = np.bincount(second)
        code = int(np.argmax(counts))
        s2 = float(counts[code]) / second.shape[0]
        s1 = float(np.mean(first == code))
        delta = s2 - s1
        projected = min(s2 + delta * (lead / half), 1.0)
        if delta >= self.trend_min_delta and projected >= self.trend_share:
            qs = self._mixture_sample(code, projected, second)
            if qs:
                return Forecast(key=self._key_of_code(code), queries=qs,
                                source="trend", confidence=projected,
                                dwell=self.trend_dwell, lead=lead)
        return None

    def _mixture_sample(self, code: int, share: float,
                        recent: np.ndarray) -> List[wl.Query]:
        """Blend the horizon's predicted query mix, not just the riser.

        Mid-drift the realized stream is still a mixture — a forecast of
        pure target queries makes every downstream consumer (predicted
        costs, grown trees) optimize for a regime that hasn't arrived,
        which mis-prices pre-positions while the old template still
        carries real mass.  ``share`` of the sample comes from the rising
        key; the rest is filled from the other keys in proportion to
        their weight in the recent window.
        """
        total = self.samples_per_key
        take = {code: int(round(share * total))}
        rest = total - take[code]
        if rest > 0:
            other = recent[recent != code]
            if other.size:
                ocounts = np.bincount(other)
                for c in np.flatnonzero(ocounts):
                    take[int(c)] = int(round(
                        rest * float(ocounts[c]) / other.size))
        qs: List[wl.Query] = []
        for c, k in take.items():
            pool = self._samples.get(c, ())
            qs.extend(list(pool)[-k:] if k > 0 else [])
        return qs

    def info(self) -> dict:
        return {"forecaster": self.name, "observed": self.observed,
                "distinct_keys": len(self._code_of)}


class AdversarialForecaster:
    """Always-wrong forecaster for the worst-case golden tests.

    Predicts the *mirror image* of the recent predicate ranges within the
    observed per-column domain (``lo' = dom_lo + dom_hi - hi``), under a
    sentinel key no realized query ever carries — so its predictions are
    maximally actionable-looking (the predicted-best layout genuinely
    differs from the current one) yet never come true.  The α-safety
    clamp in :class:`repro.forecast.policy.ForecastPolicy` is what keeps
    the damage bounded; the golden tests drive this probe to prove it.
    """

    name = "adversarial"

    def __init__(self, samples: int = 32, dwell: float = 1e6):
        self.samples = int(samples)
        self.dwell = float(dwell)
        self._recent: Deque[wl.Query] = collections.deque(maxlen=samples)
        self._dom_lo: Optional[np.ndarray] = None
        self._dom_hi: Optional[np.ndarray] = None
        self.observed = 0

    def observe(self, query: wl.Query) -> None:
        self._recent.append(query)
        finite_lo = np.where(np.isfinite(query.lo), query.lo, np.inf)
        finite_hi = np.where(np.isfinite(query.hi), query.hi, -np.inf)
        if self._dom_lo is None:
            self._dom_lo, self._dom_hi = finite_lo, finite_hi
        else:
            self._dom_lo = np.minimum(self._dom_lo, finite_lo)
            self._dom_hi = np.maximum(self._dom_hi, finite_hi)
        self.observed += 1

    def _mirror(self, query: wl.Query) -> wl.Query:
        lo, hi = query.lo, query.hi
        finite = np.isfinite(lo) & np.isfinite(hi)
        # unbounded columns have inf/-inf domain sentinels whose sum is
        # nan; they are masked out anyway, so fold them to 0 first
        span = np.where(finite, self._dom_lo, 0.0) \
            + np.where(finite, self._dom_hi, 0.0)
        m_lo = np.where(finite, span - hi, lo)
        m_hi = np.where(finite, span - lo, hi)
        return wl.Query(lo=m_lo, hi=m_hi, template_id=-1)

    def forecast(self, lead: int = 20) -> Optional[Forecast]:
        if not self._recent:
            return None
        qs = [self._mirror(q) for q in self._recent]
        return Forecast(key=("adversarial-sentinel",), queries=qs,
                        source="adversarial", confidence=1.0,
                        dwell=self.dwell, lead=lead)

    def info(self) -> dict:
        return {"forecaster": self.name, "observed": self.observed}
