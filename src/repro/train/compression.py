"""Error-feedback int8 gradient compression.

Cross-pod gradient all-reduce is the lowest-bandwidth collective in the
multi-pod mesh; quantizing gradients to int8 with an error-feedback residual
(1-bit-Adam/EF-SGD family) cuts the cross-pod bytes 4x (fp32) / 2x (bf16)
while the residual keeps the *accumulated* quantization error unbiased.

``ef_int8_roundtrip`` implements quantize -> (all-reduce happens on the
quantized representation in the partitioned program) -> dequantize with the
carried residual.  In the single-program SPMD form the quantization is
applied to the already-summed gradient; the collective itself is lowered by
XLA -- the compression transform bounds the bytes the cross-pod axis must
carry, which the roofline collective term reads off the compiled HLO.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp


def init_residual(params) -> Dict:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _quantize(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def ef_int8_roundtrip(grads, residual) -> Tuple[Dict, Dict]:
    """Returns (dequantized grads, new residual)."""

    def one(g, r):
        g32 = g.astype(jnp.float32) + r
        q, scale = _quantize(g32)
        deq = q.astype(jnp.float32) * scale
        return deq.astype(g.dtype), g32 - deq

    out = jax.tree.map(one, grads, residual)
    treedef = jax.tree.structure(grads)
    flat = treedef.flatten_up_to(out)
    new_grads = treedef.unflatten([t[0] for t in flat])
    new_res = treedef.unflatten([t[1] for t in flat])
    return new_grads, new_res
