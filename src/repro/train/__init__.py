"""Training substrate: optimizer, train-step builder, checkpointing,
fault tolerance / elastic scaling, gradient compression."""
from repro.train import checkpoint, compression, elastic, optimizer, train_loop
from repro.train.elastic import FaultTolerantTrainer, Prefetcher, remesh
from repro.train.optimizer import OptimizerConfig, adamw_update, init_opt_state
from repro.train.train_loop import (TrainOptions, build_train_step,
                                    init_train_state, train_state_specs)

__all__ = ["FaultTolerantTrainer", "OptimizerConfig", "Prefetcher",
           "TrainOptions", "adamw_update", "build_train_step", "checkpoint",
           "compression", "elastic", "init_opt_state", "init_train_state",
           "optimizer", "remesh", "train_loop", "train_state_specs"]
