"""AdamW + warmup-cosine schedule, built from scratch (no optax).

Optimizer moment dtype is configurable (``state_dtype``): fp32 is the
default; bf16 halves optimizer HBM -- the memory-roofline lever used for the
nemotron-4-340b fit (EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    peak_lr: float = 3e-4
    min_lr: float = 3e-5
    warmup_steps: int = 100
    total_steps: int = 10000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state_dtype: str = "float32"          # "float32" | "bfloat16"


def schedule(step: jax.Array, cfg: OptimizerConfig) -> jax.Array:
    """Linear warmup -> cosine decay to min_lr."""
    step = step.astype(jnp.float32)
    warm = cfg.peak_lr * step / max(cfg.warmup_steps, 1)
    progress = jnp.clip((step - cfg.warmup_steps)
                        / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr + 0.5 * (cfg.peak_lr - cfg.min_lr) * (
        1.0 + jnp.cos(jnp.pi * progress))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params, cfg: OptimizerConfig) -> Dict:
    dt = jnp.dtype(cfg.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_update(params, grads, opt_state: Dict, cfg: OptimizerConfig
                 ) -> Tuple[Dict, Dict, Dict]:
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    lr = schedule(step, cfg)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    dt = jnp.dtype(cfg.state_dtype)
    bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:                                  # decay matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * delta
        return new_p.astype(p.dtype), m32.astype(dt), v32.astype(dt)

    out = jax.tree.map(upd, params, grads, opt_state["m"], opt_state["v"])
    treedef = jax.tree.structure(params)
    flat = treedef.flatten_up_to(out)
    new_params = treedef.unflatten([t[0] for t in flat])
    new_m = treedef.unflatten([t[1] for t in flat])
    new_v = treedef.unflatten([t[2] for t in flat])
    return new_params, {"m": new_m, "v": new_v, "step": step}, \
        {"lr": lr, "grad_norm": gnorm}
