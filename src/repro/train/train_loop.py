"""Train-step builder: grad-accumulation microbatching, remat, sharded state.

``build_train_step`` returns a pure ``(state, batch) -> (state, metrics)``
function suitable for jit/lowering on any mesh.  Gradient accumulation scans
over microbatches (activation-memory lever); optional error-feedback int8
gradient compression (cross-pod bandwidth lever) plugs in between grad
computation and the optimizer.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.factory import ModelBundle
from repro.train import compression, optimizer as opt


@dataclasses.dataclass(frozen=True)
class TrainOptions:
    microbatches: int = 1
    accum_dtype: str = "float32"
    compress_grads: bool = False           # error-feedback int8 (cross-pod)


def init_train_state(model: ModelBundle, key, opt_cfg: opt.OptimizerConfig,
                     options: Optional[TrainOptions] = None) -> Dict:
    params = model.init_params(key)
    state = {"params": params, "opt": opt.init_opt_state(params, opt_cfg)}
    if options and options.compress_grads:
        state["ef_residual"] = compression.init_residual(params)
    return state


def train_state_specs(model: ModelBundle,
                      options: Optional[TrainOptions] = None) -> Dict:
    pspecs = model.param_specs()
    specs = {"params": pspecs,
             "opt": {"m": pspecs, "v": pspecs, "step": P()}}
    if options and options.compress_grads:
        specs["ef_residual"] = pspecs
    return specs


def build_train_step(model: ModelBundle, opt_cfg: opt.OptimizerConfig,
                     options: Optional[TrainOptions] = None) -> Callable:
    options = options or TrainOptions()
    n_micro = options.microbatches

    def train_step(state: Dict, batch: Dict) -> Tuple[Dict, Dict]:
        params = state["params"]

        if n_micro == 1:
            loss, grads = jax.value_and_grad(model.loss_fn)(params, batch)
        else:
            acc_dt = jnp.dtype(options.accum_dtype)

            def split(x):
                b = x.shape[0]
                assert b % n_micro == 0, (b, n_micro)
                return x.reshape((n_micro, b // n_micro) + x.shape[1:])

            micro = jax.tree.map(split, batch)

            def acc_body(carry, mb):
                loss_sum, g_acc = carry
                loss, g = jax.value_and_grad(model.loss_fn)(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(acc_dt), g_acc, g)
                return (loss_sum + loss, g_acc), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt), params)
            (loss_sum, grads), _ = jax.lax.scan(
                acc_body, (jnp.zeros((), jnp.float32), g0), micro)
            loss = loss_sum / n_micro
            grads = jax.tree.map(lambda g: g / n_micro, grads)

        if options.compress_grads:
            grads, residual = compression.ef_int8_roundtrip(
                grads, state["ef_residual"])

        new_params, new_opt, metrics = opt.adamw_update(
            params, grads, state["opt"], opt_cfg)
        new_state = {"params": new_params, "opt": new_opt}
        if options.compress_grads:
            new_state["ef_residual"] = residual
        metrics = dict(metrics, loss=loss)
        return new_state, metrics

    return train_step
