"""Fault tolerance + elastic scaling for the training loop.

* ``FaultTolerantTrainer`` -- checkpoint/restart driver: periodic atomic
  checkpoints, automatic restore-and-replay on step failure, deterministic
  per-step data (batches keyed by step index -> bit-exact resume).
* ``Prefetcher`` -- straggler mitigation at the host level: the next batch is
  materialized while the current step runs, so a slow host never stalls the
  collective (the standard double-buffering trick).
* ``remesh`` -- elastic rescale: checkpoints are mesh-agnostic (see
  checkpoint.py); re-entering with a different data-axis size re-shards
  params on load.  Model/tensor shardings are unchanged, so no resharding
  pass is needed beyond device_put.
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Callable, Dict, Iterator, Optional

import jax

from repro.train import checkpoint


class Prefetcher:
    """Background-thread batch prefetch (double buffering)."""

    def __init__(self, it: Iterator, depth: int = 2):
        self.it = it
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self.done = object()
        self.thread = threading.Thread(target=self._run, daemon=True)
        self.thread.start()

    def _run(self):
        try:
            for item in self.it:
                self.q.put(item)
        finally:
            self.q.put(self.done)

    def __iter__(self):
        return self

    def __next__(self):
        item = self.q.get()
        if item is self.done:
            raise StopIteration
        return item


class FaultTolerantTrainer:
    """Runs ``train_step`` with checkpoint/restart semantics.

    ``batch_fn(step) -> batch`` must be deterministic in ``step`` so that
    recovery replays the exact same data order (bit-exact resume).
    ``fault_hook(step)`` lets tests inject failures at chosen steps.
    """

    def __init__(self, train_step: Callable, state: Any,
                 batch_fn: Callable[[int], Dict],
                 ckpt_dir: str, ckpt_every: int = 10,
                 max_restarts: int = 3,
                 fault_hook: Optional[Callable[[int], None]] = None):
        self.train_step = train_step
        self.state = state
        self.batch_fn = batch_fn
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.max_restarts = max_restarts
        self.fault_hook = fault_hook
        self.metrics_log = []
        self.restarts = 0

    def _restore(self) -> int:
        step = checkpoint.latest_step(self.ckpt_dir)
        if step is None:
            return 0
        self.state = checkpoint.restore(self.ckpt_dir, step, self.state)
        return step

    def run(self, num_steps: int, start_step: int = 0) -> Any:
        step = start_step
        while step < num_steps:
            try:
                if self.fault_hook is not None:
                    self.fault_hook(step)
                batch = self.batch_fn(step)
                self.state, metrics = self.train_step(self.state, batch)
                self.metrics_log.append(
                    {k: float(v) for k, v in metrics.items()} | {"step": step})
                step += 1
                if step % self.ckpt_every == 0:
                    checkpoint.save(self.state, self.ckpt_dir, step)
            except (RuntimeError, ValueError, FloatingPointError) as e:
                # Node failure / NaN blow-up: restore + replay.
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise RuntimeError(
                        f"exceeded {self.max_restarts} restarts") from e
                step = self._restore()
        checkpoint.save(self.state, self.ckpt_dir, step)
        return self.state


def remesh(state: Any, shardings: Any) -> Any:
    """Elastic rescale: move a state pytree onto new shardings (new mesh)."""
    return jax.tree.map(
        lambda x, s: jax.device_put(x, s), state, shardings)
