"""Distributed checkpointing: per-leaf shard files + manifest, atomic commit.

Layout on disk:
    <dir>/step_<N>/manifest.json        tree structure + leaf dtypes/shapes
    <dir>/step_<N>/leaf_<i>.npy         one file per pytree leaf

Multi-host semantics: each process writes only its addressable shards (here:
single-process writes everything); the manifest carries the step and the
flattened tree structure so restore is layout-independent -- reloading onto a
*different* mesh (elastic re-shard) just means device_put with new shardings.
Commit is atomic (tmp dir + rename), so a failure mid-save never corrupts the
latest checkpoint.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, List, Optional

import jax
import numpy as np


def save(state: Any, directory: str, step: int, keep_last: int = 3) -> str:
    leaves, treedef = jax.tree.flatten(state)
    tmp = os.path.join(directory, f".tmp_step_{step}")
    final = os.path.join(directory, f"step_{step}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    dtypes = []
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        dtypes.append(str(arr.dtype) if arr.dtype.kind != "V" else "bfloat16")
        if arr.dtype.kind == "V":            # bfloat16: persist as uint16
            arr = arr.view(np.uint16)
        np.save(os.path.join(tmp, f"leaf_{i}.npy"), arr)
    manifest = {
        "step": step,
        "num_leaves": len(leaves),
        "treedef": str(treedef),
        "dtypes": dtypes,
        "shapes": [list(np.shape(l)) for l in leaves],
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                      # atomic commit
    _cleanup(directory, keep_last)
    return final


def _cleanup(directory: str, keep_last: int) -> None:
    steps = sorted(all_steps(directory))
    for s in steps[:-keep_last] if keep_last > 0 else []:
        shutil.rmtree(os.path.join(directory, f"step_{s}"), ignore_errors=True)


def all_steps(directory: str) -> List[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if name.startswith("step_"):
            try:
                out.append(int(name.split("_")[1]))
            except ValueError:
                pass
    return sorted(out)


def latest_step(directory: str) -> Optional[int]:
    steps = all_steps(directory)
    return steps[-1] if steps else None


def restore(directory: str, step: int, like: Any,
            shardings: Any = None) -> Any:
    """Restore into the structure of ``like`` (a state pytree or shapes).

    ``shardings`` (optional pytree of NamedSharding) re-shards onto the
    current mesh -- this is the elastic-rescale path: the on-disk format is
    mesh-agnostic, so growing/shrinking the data axis is a plain reload.
    """
    path = os.path.join(directory, f"step_{step}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    leaves, treedef = jax.tree.flatten(like)
    assert manifest["num_leaves"] == len(leaves), \
        (manifest["num_leaves"], len(leaves))
    loaded = []
    for i in range(len(leaves)):
        arr = np.load(os.path.join(path, f"leaf_{i}.npy"))
        if manifest["dtypes"][i] == "bfloat16":
            arr = jax.lax.bitcast_convert_type(
                jax.numpy.asarray(arr), jax.numpy.bfloat16)
        loaded.append(arr)
    state = jax.tree.unflatten(treedef, loaded)
    if shardings is not None:
        state = jax.tree.map(
            lambda x, s: jax.device_put(x, s) if s is not None else
        jax.numpy.asarray(x), state, shardings)
    else:
        state = jax.tree.map(jax.numpy.asarray, state)
    return state
